//! Structured timing and counters for every run.
//!
//! Two clocks exist in this system and every report keeps them separate:
//!
//! * **wall time** — real measured nanoseconds of our single-machine run;
//! * **sim time** — the modelled Hadoop-cluster time from
//!   [`crate::mapreduce::simclock`], which charges job/task/shuffle overheads
//!   the paper's physical testbed paid but a single process does not.
//!
//! The table-regeneration harness reports `modelled = sim + scaled-wall`, the
//! way DESIGN.md §3 documents the substitution.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::json::{self, Value};

/// A single named timing span.
#[derive(Clone, Debug)]
pub struct Span {
    pub name: String,
    pub wall: Duration,
}

/// Collects spans and counters for one run; cheap to clone snapshots out of.
#[derive(Default)]
pub struct Telemetry {
    spans: Mutex<Vec<Span>>,
    counters: Mutex<BTreeMap<String, u64>>,
}

impl Telemetry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        self.record(name, start.elapsed());
        out
    }

    /// Record an externally measured span.
    pub fn record(&self, name: &str, wall: Duration) {
        self.spans
            .lock()
            .expect("telemetry poisoned")
            .push(Span { name: name.to_string(), wall });
    }

    /// Increment a named counter.
    pub fn incr(&self, name: &str, by: u64) {
        *self
            .counters
            .lock()
            .expect("telemetry poisoned")
            .entry(name.to_string())
            .or_insert(0) += by;
    }

    /// Total wall time across spans with this name.
    pub fn total(&self, name: &str) -> Duration {
        self.spans
            .lock()
            .expect("telemetry poisoned")
            .iter()
            .filter(|s| s.name == name)
            .map(|s| s.wall)
            .sum()
    }

    /// Counter value (0 if never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .expect("telemetry poisoned")
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// Snapshot all spans.
    pub fn spans(&self) -> Vec<Span> {
        self.spans.lock().expect("telemetry poisoned").clone()
    }

    /// Serialise to a JSON report object.
    pub fn to_json(&self) -> Value {
        let spans = self.spans();
        let mut by_name: BTreeMap<String, (u64, f64)> = BTreeMap::new();
        for s in &spans {
            let e = by_name.entry(s.name.clone()).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += s.wall.as_secs_f64();
        }
        let span_obj = Value::Object(
            by_name
                .into_iter()
                .map(|(k, (n, secs))| {
                    (
                        k,
                        json::obj(vec![
                            ("count", json::num(n as f64)),
                            ("total_s", json::num(secs)),
                        ]),
                    )
                })
                .collect(),
        );
        let counters = Value::Object(
            self.counters
                .lock()
                .expect("telemetry poisoned")
                .iter()
                .map(|(k, &v)| (k.clone(), json::num(v as f64)))
                .collect(),
        );
        json::obj(vec![("spans", span_obj), ("counters", counters)])
    }
}

/// A monotonically accumulating nanosecond cell, safe to bump from workers.
#[derive(Default)]
pub struct AtomicDuration {
    nanos: AtomicU64,
}

impl AtomicDuration {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, d: Duration) {
        self.nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn get(&self) -> Duration {
        Duration::from_nanos(self.nanos.load(Ordering::Relaxed))
    }
}

/// Format a duration the way the paper's tables do (seconds, or m/h).
pub fn human_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 120.0 {
        format!("{s:.1}s")
    } else if s < 7200.0 {
        format!("{:.1}m", s / 60.0)
    } else if s < 48.0 * 3600.0 {
        format!("{:.1}h", s / 3600.0)
    } else {
        format!("{:.1}d", s / 86400.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_spans_and_counters() {
        let t = Telemetry::new();
        let v = t.time("work", || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(t.total("work") >= Duration::from_millis(4));
        t.incr("chunks", 3);
        t.incr("chunks", 2);
        assert_eq!(t.counter("chunks"), 5);
        assert_eq!(t.counter("missing"), 0);
    }

    #[test]
    fn json_report_shape() {
        let t = Telemetry::new();
        t.record("phase", Duration::from_millis(10));
        t.record("phase", Duration::from_millis(20));
        t.incr("n", 1);
        let j = t.to_json();
        let phase = j.get("spans").unwrap().get("phase").unwrap();
        assert_eq!(phase.get("count").unwrap().as_f64(), Some(2.0));
        assert!(phase.get("total_s").unwrap().as_f64().unwrap() >= 0.029);
        assert_eq!(j.get("counters").unwrap().get("n").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn atomic_duration_accumulates() {
        let d = AtomicDuration::new();
        d.add(Duration::from_millis(3));
        d.add(Duration::from_millis(4));
        assert_eq!(d.get(), Duration::from_millis(7));
    }

    #[test]
    fn human_duration_bands() {
        assert_eq!(human_duration(Duration::from_secs(30)), "30.0s");
        assert_eq!(human_duration(Duration::from_secs(600)), "10.0m");
        assert_eq!(human_duration(Duration::from_secs(7200)), "2.0h");
        assert_eq!(human_duration(Duration::from_secs(200_000)), "2.3d");
    }
}
