//! Structured hierarchical tracing with Chrome-trace export.
//!
//! Spans form a tree (`session > iteration > job > shard > map_task /
//! combine / spill / prefetch`; `serve > batch > score_chunk`) and are
//! recorded **lock-cheaply** into a small set of sharded buffers indexed by
//! a per-thread id, then merged at [`Tracer::drain`] — a worker thread only
//! ever contends with the (rare) other thread hashing to the same shard, and
//! no span recorded before the drain can be lost to an unflushed
//! thread-local. Memory is bounded: past [`Tracer::set_max_spans`] new spans
//! degrade to a per-`(cat, name)` aggregation row (count + total µs), the
//! same shape as the serve latency reservoir. When tracing is disabled the
//! record path is one relaxed atomic load.
//!
//! Instrumentation must never kill a run: every internal lock degrades to
//! the poisoned inner value instead of panicking, and a full buffer drops
//! to aggregation instead of erroring.
//!
//! Two handle styles cover the call sites:
//!
//! * [`SpanGuard`] — RAII + *ambient*: the span is pushed onto a
//!   thread-local stack so child spans opened on the same thread nest under
//!   it automatically; recorded at drop (covers `?` early returns).
//! * [`ManualSpan`] — explicit begin/end across threads (the serve root
//!   lives in the service's shared state and is ended at `close()`); plus
//!   [`Tracer::record_manual`] for after-the-fact spans whose duration was
//!   measured elsewhere (per-shard walls merged on the driver).

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

use crate::json::{self, Value};

/// Buffer shards: threads hash `tid % NBUF`, so contention is rare without
/// unbounded per-thread state.
const NBUF: usize = 16;

/// `(cat, name)` → `(count, total µs)` rollup of spans past the cap.
type AggMap = BTreeMap<(&'static str, &'static str), (u64, u64)>;

/// Default retained-span cap (past it, spans aggregate).
pub const DEFAULT_MAX_SPANS: usize = 262_144;

/// Lock that degrades to the inner value on poison — instrumentation must
/// never propagate a worker panic into a second failure.
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static THREAD_NAMES: Mutex<Vec<(u64, String)>> = Mutex::new(Vec::new());

thread_local! {
    /// Small dense per-thread id (0 = unassigned), assigned on first use.
    static TID: Cell<u64> = const { Cell::new(0) };
    /// Ambient span stack: `(span id, span name)` innermost-last.
    static AMBIENT: RefCell<Vec<(u64, &'static str)>> = const { RefCell::new(Vec::new()) };
}

/// The innermost ambient span id open on this thread (0 = none) — capture
/// it before handing work to another thread so spans there can parent here.
pub fn current_span_id() -> u64 {
    AMBIENT.with(|s| s.borrow().last().map(|&(id, _)| id).unwrap_or(0))
}

/// This thread's dense trace id, registering its name on first use.
fn current_tid() -> u64 {
    TID.with(|c| {
        let mut t = c.get();
        if t == 0 {
            t = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            c.set(t);
            let name = std::thread::current()
                .name()
                .map(|s| s.to_string())
                .unwrap_or_else(|| format!("thread-{t}"));
            relock(&THREAD_NAMES).push((t, name));
        }
        t
    })
}

/// One finished span.
#[derive(Clone, Debug)]
pub struct SpanRec {
    pub id: u64,
    /// Parent span id; 0 means root.
    pub parent: u64,
    pub name: &'static str,
    /// Coarse category (`session`, `mapreduce`, `serve`, ...) — the Chrome
    /// `cat` field, also the aggregation key prefix.
    pub cat: &'static str,
    /// Dense recording-thread id (Chrome `tid`).
    pub tid: u64,
    /// Start, µs since the tracer epoch.
    pub start_us: u64,
    /// Duration in µs; `u64` by construction, so durations are never
    /// negative.
    pub dur_us: u64,
    pub attrs: Vec<(&'static str, String)>,
}

/// Aggregation row for spans past the retention cap.
#[derive(Clone, Debug)]
pub struct AggRow {
    pub cat: &'static str,
    pub name: &'static str,
    pub count: u64,
    pub total_us: u64,
}

/// Everything [`Tracer::drain`] hands back.
#[derive(Clone, Debug, Default)]
pub struct TraceData {
    /// Retained spans, sorted by start time.
    pub spans: Vec<SpanRec>,
    /// Per-`(cat, name)` rollups of spans dropped past the cap.
    pub aggregated: Vec<AggRow>,
    /// Count of spans that went to aggregation instead of retention.
    pub dropped: u64,
    /// `(tid, thread name)` for every thread that recorded a span.
    pub threads: Vec<(u64, String)>,
}

impl TraceData {
    /// Total retained duration of spans with this name, in seconds.
    pub fn total_s(&self, name: &str) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.name == name)
            .map(|s| s.dur_us as f64 / 1e6)
            .sum()
    }

    /// Retained spans with this name.
    pub fn by_name(&self, name: &str) -> Vec<&SpanRec> {
        self.spans.iter().filter(|s| s.name == name).collect()
    }
}

/// The span collector. One process-global instance serves the binaries
/// ([`global`]); tests construct their own to stay isolated.
pub struct Tracer {
    enabled: AtomicBool,
    slow_span_us: AtomicU64,
    max_spans: AtomicUsize,
    next_id: AtomicU64,
    epoch: Instant,
    bufs: Vec<Mutex<Vec<SpanRec>>>,
    retained: AtomicUsize,
    agg: Mutex<AggMap>,
    dropped: AtomicU64,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tracer {
    pub fn new() -> Self {
        Tracer {
            enabled: AtomicBool::new(false),
            slow_span_us: AtomicU64::new(0),
            max_spans: AtomicUsize::new(DEFAULT_MAX_SPANS),
            next_id: AtomicU64::new(1),
            epoch: Instant::now(),
            bufs: (0..NBUF).map(|_| Mutex::new(Vec::new())).collect(),
            retained: AtomicUsize::new(0),
            agg: Mutex::new(BTreeMap::new()),
            dropped: AtomicU64::new(0),
        }
    }

    /// Turn recording on or off. Off (the default) makes every span call a
    /// single relaxed load.
    pub fn enable(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Spans at least this long are logged with their ancestry as they are
    /// recorded (0 disables, the default).
    pub fn set_slow_span_us(&self, us: u64) {
        self.slow_span_us.store(us, Ordering::Relaxed);
    }

    /// Retention cap; spans past it degrade to aggregation rows.
    pub fn set_max_spans(&self, cap: usize) {
        self.max_spans.store(cap.max(1), Ordering::Relaxed);
    }

    fn alloc_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Open an ambient span: parent is the innermost span already open on
    /// this thread.
    pub fn span(&self, name: &'static str, cat: &'static str) -> SpanGuard<'_> {
        let parent = if self.enabled() {
            AMBIENT.with(|s| s.borrow().last().map(|&(id, _)| id).unwrap_or(0))
        } else {
            0
        };
        self.span_child(name, cat, parent)
    }

    /// Open an ambient span under an explicit parent id (0 = root). Used
    /// where the logical parent lives on another thread (map tasks under
    /// the driver's job span).
    pub fn span_child(&self, name: &'static str, cat: &'static str, parent: u64) -> SpanGuard<'_> {
        if !self.enabled() {
            return SpanGuard {
                tracer: self,
                id: 0,
                parent: 0,
                name,
                cat,
                start: self.epoch,
                attrs: Vec::new(),
                dur_override: None,
            };
        }
        let id = self.alloc_id();
        AMBIENT.with(|s| s.borrow_mut().push((id, name)));
        SpanGuard {
            tracer: self,
            id,
            parent,
            name,
            cat,
            start: Instant::now(),
            attrs: Vec::new(),
            dur_override: None,
        }
    }

    /// Begin an explicit (non-ambient) span that another thread may end
    /// later via [`Tracer::end`]. Returns an inert span while disabled.
    pub fn begin(&self, name: &'static str, cat: &'static str, parent: u64) -> ManualSpan {
        let id = if self.enabled() { self.alloc_id() } else { 0 };
        ManualSpan { id, parent, name, cat, start: Instant::now() }
    }

    /// End a [`ManualSpan`], measuring its duration from `begin`.
    pub fn end(&self, span: &ManualSpan, attrs: Vec<(&'static str, String)>) {
        if span.id == 0 || !self.enabled() {
            return;
        }
        let start_us = span.start.saturating_duration_since(self.epoch).as_micros() as u64;
        self.record(SpanRec {
            id: span.id,
            parent: span.parent,
            name: span.name,
            cat: span.cat,
            tid: current_tid(),
            start_us,
            dur_us: span.start.elapsed().as_micros() as u64,
            attrs,
        });
    }

    /// Record a span after the fact with an externally measured duration
    /// (ends now). Returns the span id (0 while disabled).
    pub fn record_manual(
        &self,
        name: &'static str,
        cat: &'static str,
        parent: u64,
        dur: Duration,
        attrs: Vec<(&'static str, String)>,
    ) -> u64 {
        if !self.enabled() {
            return 0;
        }
        let id = self.alloc_id();
        let now_us = self.epoch.elapsed().as_micros() as u64;
        let dur_us = dur.as_micros() as u64;
        self.record(SpanRec {
            id,
            parent,
            name,
            cat,
            tid: current_tid(),
            start_us: now_us.saturating_sub(dur_us),
            dur_us,
            attrs,
        });
        id
    }

    fn record(&self, rec: SpanRec) {
        let slow = self.slow_span_us.load(Ordering::Relaxed);
        if slow > 0 && rec.dur_us >= slow {
            let ancestry = AMBIENT.with(|s| {
                s.borrow().iter().map(|&(_, n)| n).collect::<Vec<_>>().join(" > ")
            });
            eprintln!(
                "trace: slow span `{}` ({} µs >= {} µs) under [{}]",
                rec.name, rec.dur_us, slow, ancestry
            );
        }
        if self.retained.load(Ordering::Relaxed) >= self.max_spans.load(Ordering::Relaxed) {
            let mut agg = relock(&self.agg);
            let e = agg.entry((rec.cat, rec.name)).or_insert((0, 0));
            e.0 += 1;
            e.1 += rec.dur_us;
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.retained.fetch_add(1, Ordering::Relaxed);
        relock(&self.bufs[(rec.tid as usize) % NBUF]).push(rec);
    }

    /// Merge every shard buffer into one sorted trace and clear the
    /// collector (enabled state and knobs are kept).
    pub fn drain(&self) -> TraceData {
        let mut spans: Vec<SpanRec> = Vec::with_capacity(self.retained.load(Ordering::Relaxed));
        for buf in &self.bufs {
            spans.append(&mut relock(buf));
        }
        spans.sort_by_key(|s| (s.start_us, s.id));
        self.retained.store(0, Ordering::Relaxed);
        let aggregated = std::mem::take(&mut *relock(&self.agg))
            .into_iter()
            .map(|((cat, name), (count, total_us))| AggRow { cat, name, count, total_us })
            .collect();
        let dropped = self.dropped.swap(0, Ordering::Relaxed);
        let tids: std::collections::BTreeSet<u64> = spans.iter().map(|s| s.tid).collect();
        let threads = relock(&THREAD_NAMES)
            .iter()
            .filter(|(t, _)| tids.contains(t))
            .cloned()
            .collect();
        TraceData { spans, aggregated, dropped, threads }
    }

    /// Drop everything collected so far without returning it.
    pub fn reset(&self) {
        let _ = self.drain();
    }
}

/// RAII ambient span: records at drop with measured (or overridden)
/// duration, and keeps the thread-local ancestry stack honest.
pub struct SpanGuard<'a> {
    tracer: &'a Tracer,
    id: u64,
    parent: u64,
    name: &'static str,
    cat: &'static str,
    start: Instant,
    attrs: Vec<(&'static str, String)>,
    dur_override: Option<Duration>,
}

impl SpanGuard<'_> {
    /// Attach a key=value attribute.
    pub fn attr(&mut self, key: &'static str, value: String) {
        if self.id != 0 {
            self.attrs.push((key, value));
        }
    }

    /// Override the recorded duration (e.g. stamp the exact `JobStats`
    /// wall so span totals and the report agree by construction).
    pub fn set_dur(&mut self, dur: Duration) {
        self.dur_override = Some(dur);
    }

    /// The span id (0 while tracing is disabled) — pass to
    /// [`Tracer::span_child`] on other threads.
    pub fn id(&self) -> u64 {
        self.id
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if self.id == 0 {
            return;
        }
        AMBIENT.with(|s| {
            let mut st = s.borrow_mut();
            if let Some(pos) = st.iter().rposition(|&(id, _)| id == self.id) {
                st.remove(pos);
            }
        });
        let dur = self.dur_override.unwrap_or_else(|| self.start.elapsed());
        let start_us = self.start.saturating_duration_since(self.tracer.epoch).as_micros() as u64;
        self.tracer.record(SpanRec {
            id: self.id,
            parent: self.parent,
            name: self.name,
            cat: self.cat,
            tid: current_tid(),
            start_us,
            dur_us: dur.as_micros() as u64,
            attrs: std::mem::take(&mut self.attrs),
        });
    }
}

/// Explicit begin/end span; plain data, safe to store in shared state and
/// end from another thread.
#[derive(Clone, Debug)]
pub struct ManualSpan {
    pub id: u64,
    parent: u64,
    name: &'static str,
    cat: &'static str,
    start: Instant,
}

static GLOBAL: OnceLock<Tracer> = OnceLock::new();

/// The process-global tracer the binaries record into.
pub fn global() -> &'static Tracer {
    GLOBAL.get_or_init(Tracer::new)
}

/// Render a drained trace as Chrome `chrome://tracing` / Perfetto JSON.
///
/// Retained spans become `ph:"X"` complete events on pid 1 with one row per
/// recording thread; `sim` rows (modelled cost classes, `(label, seconds)`)
/// are laid end-to-end on pid 2 so the modelled breakdown reads as a bar
/// next to the measured timeline. Parents that were aggregated away are
/// remapped to the root so the tree always resolves.
pub fn chrome_trace_json(data: &TraceData, sim: &[(&str, f64)]) -> String {
    let ids: std::collections::BTreeSet<u64> = data.spans.iter().map(|s| s.id).collect();
    let mut events: Vec<Value> = Vec::with_capacity(data.spans.len() + data.threads.len() + 8);
    events.push(json::obj(vec![
        ("name", json::s("process_name")),
        ("ph", json::s("M")),
        ("pid", json::num(1.0)),
        ("tid", json::num(0.0)),
        ("args", json::obj(vec![("name", json::s("bigfcm"))])),
    ]));
    for (tid, name) in &data.threads {
        events.push(json::obj(vec![
            ("name", json::s("thread_name")),
            ("ph", json::s("M")),
            ("pid", json::num(1.0)),
            ("tid", json::num(*tid as f64)),
            ("args", json::obj(vec![("name", json::s(name.as_str()))])),
        ]));
    }
    for rec in &data.spans {
        let parent = if rec.parent != 0 && !ids.contains(&rec.parent) { 0 } else { rec.parent };
        let mut args = vec![
            ("id".to_string(), json::num(rec.id as f64)),
            ("parent".to_string(), json::num(parent as f64)),
        ];
        for (k, v) in &rec.attrs {
            args.push((k.to_string(), json::s(v.as_str())));
        }
        events.push(json::obj(vec![
            ("name", json::s(rec.name)),
            ("cat", json::s(rec.cat)),
            ("ph", json::s("X")),
            ("pid", json::num(1.0)),
            ("tid", json::num(rec.tid as f64)),
            ("ts", json::num(rec.start_us as f64)),
            ("dur", json::num(rec.dur_us as f64)),
            ("args", Value::Object(args.into_iter().collect())),
        ]));
    }
    if !sim.is_empty() {
        events.push(json::obj(vec![
            ("name", json::s("process_name")),
            ("ph", json::s("M")),
            ("pid", json::num(2.0)),
            ("tid", json::num(0.0)),
            ("args", json::obj(vec![("name", json::s("sim-clock (modelled)"))])),
        ]));
        let mut at = 0.0f64;
        for &(label, secs) in sim {
            if secs <= 0.0 {
                continue;
            }
            events.push(json::obj(vec![
                ("name", json::s(label)),
                ("cat", json::s("sim")),
                ("ph", json::s("X")),
                ("pid", json::num(2.0)),
                ("tid", json::num(0.0)),
                ("ts", json::num(at)),
                ("dur", json::num(secs * 1e6)),
            ]));
            at += secs * 1e6;
        }
    }
    if data.dropped > 0 {
        events.push(json::obj(vec![
            ("name", json::s(format!("trace capped: {} spans aggregated", data.dropped))),
            ("ph", json::s("i")),
            ("pid", json::num(1.0)),
            ("tid", json::num(0.0)),
            ("ts", json::num(0.0)),
            ("s", json::s("g")),
        ]));
    }
    let doc = json::obj(vec![
        ("traceEvents", Value::Array(events)),
        ("displayTimeUnit", json::s("ms")),
    ]);
    json::to_string(&doc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn disabled_records_nothing() {
        let t = Tracer::new();
        {
            let mut g = t.span("root", "test");
            g.attr("k", "v".into());
        }
        t.record_manual("m", "test", 0, Duration::from_millis(1), Vec::new());
        let d = t.drain();
        assert!(d.spans.is_empty());
        assert_eq!(d.dropped, 0);
    }

    #[test]
    fn ambient_nesting_assigns_parents() {
        let t = Tracer::new();
        t.enable(true);
        let root_id;
        {
            let root = t.span("root", "test");
            root_id = root.id();
            let _child = t.span("child", "test");
        }
        let d = t.drain();
        assert_eq!(d.spans.len(), 2);
        let child = d.spans.iter().find(|s| s.name == "child").unwrap();
        assert_eq!(child.parent, root_id);
        let root = d.spans.iter().find(|s| s.name == "root").unwrap();
        assert_eq!(root.parent, 0);
    }

    #[test]
    fn set_dur_overrides_measured() {
        let t = Tracer::new();
        t.enable(true);
        {
            let mut g = t.span("it", "test");
            g.set_dur(Duration::from_micros(12_345));
        }
        let d = t.drain();
        assert_eq!(d.spans[0].dur_us, 12_345);
    }

    #[test]
    fn cap_degrades_to_aggregation() {
        let t = Tracer::new();
        t.enable(true);
        t.set_max_spans(4);
        for _ in 0..10 {
            t.record_manual("hot", "test", 0, Duration::from_micros(10), Vec::new());
        }
        let d = t.drain();
        assert_eq!(d.spans.len(), 4);
        assert_eq!(d.dropped, 6);
        assert_eq!(d.aggregated.len(), 1);
        assert_eq!(d.aggregated[0].count, 6);
        assert_eq!(d.aggregated[0].total_us, 60);
    }

    #[test]
    fn concurrent_buffers_merge_without_loss() {
        let t = Arc::new(Tracer::new());
        t.enable(true);
        let mut handles = Vec::new();
        for w in 0..4u64 {
            let t = Arc::clone(&t);
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    let mut g = t.span("work", "test");
                    g.attr("w", format!("{w}/{i}"));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let d = t.drain();
        assert_eq!(d.spans.len(), 200);
        assert_eq!(d.dropped, 0);
        // every recording thread registered a name
        let tids: std::collections::BTreeSet<u64> = d.spans.iter().map(|s| s.tid).collect();
        assert_eq!(d.threads.len(), tids.len());
    }

    #[test]
    fn manual_span_ends_cross_thread() {
        let t = Arc::new(Tracer::new());
        t.enable(true);
        let m = t.begin("serve", "serve", 0);
        let t2 = Arc::clone(&t);
        let m2 = m.clone();
        std::thread::spawn(move || t2.end(&m2, vec![("done", "yes".into())]))
            .join()
            .unwrap();
        let d = t.drain();
        assert_eq!(d.spans.len(), 1);
        assert_eq!(d.spans[0].name, "serve");
    }

    #[test]
    fn chrome_export_parses_and_parents_resolve() {
        let t = Tracer::new();
        t.enable(true);
        {
            let root = t.span("session", "session");
            let _child = t.span_child("iteration", "session", root.id());
        }
        let d = t.drain();
        let txt = chrome_trace_json(&d, &[("compute", 1.5), ("shuffle", 0.0)]);
        let v = json::parse(&txt).expect("chrome trace must parse");
        let events = match v.get("traceEvents") {
            Some(Value::Array(a)) => a,
            _ => panic!("missing traceEvents"),
        };
        let ids: Vec<f64> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .filter_map(|e| e.get("args").and_then(|a| a.get("id")).and_then(|x| x.as_f64()))
            .collect();
        for e in events {
            if let Some(p) = e.get("args").and_then(|a| a.get("parent")).and_then(|x| x.as_f64()) {
                assert!(p == 0.0 || ids.contains(&p), "dangling parent {p}");
            }
        }
    }
}
