//! Unified metrics registry: typed counter/gauge/histogram handles
//! registered by name.
//!
//! The ad-hoc counter structs (`JobStats`, `SessionRunResult`, `ServeStats`,
//! `FrontStats`) publish into one registry so the session CLI report, the
//! bench JSON and the serve wire `stats`/`metrics` verbs all read a single
//! source of truth. Handles are cheap `Arc` clones around atomics; getting
//! the same name twice returns a handle to the same cell. A name registered
//! under a conflicting type yields a *detached* handle (writes go nowhere)
//! rather than a panic — instrumentation never kills a run, the same
//! degrade-to-drop contract as [`super::trace`].
//!
//! Exposition: [`MetricsRegistry::to_json`] for the JSON replies and
//! [`MetricsRegistry::prometheus_text`] for the wire `metrics` verb
//! (Prometheus text format: dots become underscores, histograms flatten to
//! `_count` / `_sum` / `_max`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use crate::json::{self, Value};

fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Monotonic (or set-published) integer metric.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn add(&self, by: u64) {
        self.0.fetch_add(by, Ordering::Relaxed);
    }

    /// Publish an externally accumulated total (stats-struct views).
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-value float metric (stored as f64 bits in an atomic).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn add(&self, by: f64) {
        self.set(self.get() + by);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

#[derive(Default)]
struct HistState {
    count: u64,
    sum: f64,
    max: f64,
    /// log2 buckets of the observed value over `[2^-10, 2^21)`.
    buckets: [u64; 32],
}

/// Streaming distribution metric with log2 buckets (count/sum/max are the
/// exposition surface; buckets ride in the JSON snapshot).
#[derive(Clone)]
pub struct Histogram(Arc<Mutex<HistState>>);

impl Histogram {
    pub fn observe(&self, v: f64) {
        let mut st = relock(&self.0);
        st.count += 1;
        st.sum += v;
        if v > st.max {
            st.max = v;
        }
        let idx = if v > 0.0 {
            (v.log2().floor() as i64 + 10).clamp(0, 31) as usize
        } else {
            0
        };
        st.buckets[idx] += 1;
    }

    pub fn count(&self) -> u64 {
        relock(&self.0).count
    }

    pub fn sum(&self) -> f64 {
        relock(&self.0).sum
    }
}

enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

/// One metric's value at snapshot time.
#[derive(Clone, Debug, PartialEq)]
pub enum MetricValue {
    Counter(u64),
    Gauge(f64),
    Histogram { count: u64, sum: f64, max: f64 },
}

/// Named metric table. One process-global instance ([`global`]) backs the
/// binaries; tests construct their own.
#[derive(Default)]
pub struct MetricsRegistry {
    inner: Mutex<BTreeMap<String, Metric>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Counter handle for `name`, registering it on first use. A type
    /// clash returns a detached handle.
    pub fn counter(&self, name: &str) -> Counter {
        let mut inner = relock(&self.inner);
        match inner
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Counter(Arc::new(AtomicU64::new(0)))))
        {
            Metric::Counter(c) => c.clone(),
            _ => Counter(Arc::new(AtomicU64::new(0))),
        }
    }

    /// Gauge handle for `name` (detached on type clash).
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut inner = relock(&self.inner);
        match inner
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Gauge(Arc::new(AtomicU64::new(0)))))
        {
            Metric::Gauge(g) => g.clone(),
            _ => Gauge(Arc::new(AtomicU64::new(0))),
        }
    }

    /// Histogram handle for `name` (detached on type clash).
    pub fn histogram(&self, name: &str) -> Histogram {
        let mut inner = relock(&self.inner);
        match inner
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Histogram(Arc::new(Mutex::new(HistState::default())))))
        {
            Metric::Histogram(h) => h.clone(),
            _ => Histogram(Arc::new(Mutex::new(HistState::default()))),
        }
    }

    /// Convenience: publish a counter value in one call.
    pub fn set_counter(&self, name: &str, v: u64) {
        self.counter(name).set(v);
    }

    /// Convenience: publish a gauge value in one call.
    pub fn set_gauge(&self, name: &str, v: f64) {
        self.gauge(name).set(v);
    }

    /// Numeric read-back of any metric (histograms read as their sum).
    pub fn value(&self, name: &str) -> Option<f64> {
        match relock(&self.inner).get(name)? {
            Metric::Counter(c) => Some(c.get() as f64),
            Metric::Gauge(g) => Some(g.get()),
            Metric::Histogram(h) => Some(h.sum()),
        }
    }

    /// Consistent point-in-time view of every registered metric.
    pub fn snapshot(&self) -> BTreeMap<String, MetricValue> {
        relock(&self.inner)
            .iter()
            .map(|(k, m)| {
                let v = match m {
                    Metric::Counter(c) => MetricValue::Counter(c.get()),
                    Metric::Gauge(g) => MetricValue::Gauge(g.get()),
                    Metric::Histogram(h) => {
                        let st = relock(&h.0);
                        MetricValue::Histogram { count: st.count, sum: st.sum, max: st.max }
                    }
                };
                (k.clone(), v)
            })
            .collect()
    }

    /// JSON object view (`stats` wire verb, bench JSON).
    pub fn to_json(&self) -> Value {
        Value::Object(
            self.snapshot()
                .into_iter()
                .map(|(k, v)| {
                    let jv = match v {
                        MetricValue::Counter(c) => json::num(c as f64),
                        MetricValue::Gauge(g) => json::num(g),
                        MetricValue::Histogram { count, sum, max } => json::obj(vec![
                            ("count", json::num(count as f64)),
                            ("sum", json::num(sum)),
                            ("max", json::num(max)),
                        ]),
                    };
                    (k, jv)
                })
                .collect(),
        )
    }

    /// Prometheus text exposition (`metrics` wire verb).
    pub fn prometheus_text(&self) -> String {
        let mut out = String::new();
        for (name, v) in self.snapshot() {
            let base: String = name
                .chars()
                .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
                .collect();
            match v {
                MetricValue::Counter(c) => {
                    out.push_str(&format!("# TYPE {base} counter\n{base} {c}\n"));
                }
                MetricValue::Gauge(g) => {
                    out.push_str(&format!("# TYPE {base} gauge\n{base} {g}\n"));
                }
                MetricValue::Histogram { count, sum, max } => {
                    out.push_str(&format!(
                        "# TYPE {base} summary\n{base}_count {count}\n{base}_sum {sum}\n{base}_max {max}\n"
                    ));
                }
            }
        }
        out
    }

    /// Drop every registered metric (tests and fresh sessions).
    pub fn clear(&self) {
        relock(&self.inner).clear();
    }
}

static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();

/// The process-global registry the binaries publish into.
pub fn global() -> &'static MetricsRegistry {
    GLOBAL.get_or_init(MetricsRegistry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_handles_share_the_cell() {
        let r = MetricsRegistry::new();
        let a = r.counter("job.map_tasks");
        let b = r.counter("job.map_tasks");
        a.add(3);
        b.add(4);
        assert_eq!(a.get(), 7);
        assert_eq!(r.value("job.map_tasks"), Some(7.0));
    }

    #[test]
    fn type_clash_detaches_instead_of_panicking() {
        let r = MetricsRegistry::new();
        r.counter("x").add(5);
        let g = r.gauge("x"); // clash: detached
        g.set(99.0);
        assert_eq!(r.value("x"), Some(5.0));
    }

    #[test]
    fn gauge_and_histogram_roundtrip() {
        let r = MetricsRegistry::new();
        r.gauge("wall_s").set(1.5);
        let h = r.histogram("lat_s");
        h.observe(0.002);
        h.observe(0.004);
        let snap = r.snapshot();
        assert_eq!(snap.get("wall_s"), Some(&MetricValue::Gauge(1.5)));
        match snap.get("lat_s") {
            Some(&MetricValue::Histogram { count, sum, max }) => {
                assert_eq!(count, 2);
                assert!((sum - 0.006).abs() < 1e-12);
                assert!((max - 0.004).abs() < 1e-12);
            }
            other => panic!("bad histogram snapshot: {other:?}"),
        }
    }

    #[test]
    fn prometheus_text_shape() {
        let r = MetricsRegistry::new();
        r.counter("front.bytes_in").set(10);
        r.gauge("serve.p99_ms").set(1.25);
        r.histogram("serve.batch_fill").observe(8.0);
        let text = r.prometheus_text();
        assert!(text.contains("# TYPE front_bytes_in counter"));
        assert!(text.contains("front_bytes_in 10"));
        assert!(text.contains("# TYPE serve_p99_ms gauge"));
        assert!(text.contains("serve_batch_fill_count 1"));
    }

    #[test]
    fn to_json_is_an_object() {
        let r = MetricsRegistry::new();
        r.counter("a").set(1);
        let j = r.to_json();
        assert_eq!(j.get("a").and_then(|v| v.as_f64()), Some(1.0));
    }
}
