//! WFCMPB — the paper's Algorithm 2: block-wise weighted FCM.
//!
//! Splits the records into blocks sized by the sampling formula, runs FCM on
//! each block warm-started from the previous block's centers, and folds every
//! block's (centers, weights) into a running weighted-FCM merge. This is the
//! single-pass "divide and conquer" arm that the driver races against plain
//! FCM (the `Flag` decision in Algorithm 3), and the alternative combiner
//! when plain FCM converges slowly on a dataset.

use crate::data::Matrix;
use crate::error::{Error, Result};
use crate::fcm::loops::{run_fcm, FcmParams};
use crate::fcm::{KernelBackend, ClusterResult};

/// Outcome of a WFCMPB run: final merged centers/weights plus per-block
/// iteration counts (telemetry for the Flag race).
#[derive(Clone, Debug)]
pub struct WfcmpbResult {
    pub result: ClusterResult,
    pub blocks: usize,
    pub block_iterations: Vec<usize>,
}

/// Run Algorithm 2 over in-memory records.
///
/// * `block_size` — records per block S_i (from the sampling formula).
/// * `v_init` — C_intermediate seeds for the first block.
pub fn wfcmpb(
    backend: &dyn KernelBackend,
    x: &Matrix,
    v_init: Matrix,
    block_size: usize,
    params: &FcmParams,
) -> Result<WfcmpbResult> {
    if x.rows() == 0 {
        return Err(Error::Clustering("wfcmpb: empty input".into()));
    }
    let block_size = block_size.max(v_init.rows()).min(x.rows());
    let c = v_init.rows();
    let d = x.cols();

    // Accumulated (center, weight) pool across blocks: V_final ∪ C_i.
    let mut pool = Matrix::zeros(0, d);
    let mut pool_w: Vec<f64> = Vec::new();

    let mut seeds = v_init;
    let mut block_iterations = Vec::new();
    let mut blocks = 0usize;
    let mut start = 0usize;
    while start < x.rows() {
        let end = (start + block_size).min(x.rows());
        // A tail block smaller than C can't be clustered into C groups —
        // fold its records straight into the pool with unit weights.
        if end - start < c {
            for i in start..end {
                pool.push_row(x.row(i));
                pool_w.push(1.0);
            }
            break;
        }
        let block = x.slice_rows(start, end);
        let w = vec![1.0f32; block.rows()];
        // C_i, W_i = FCM(S_i, C_{i-1}, C, M) — warm start from previous.
        let r = run_fcm(backend, &block, &w, seeds.clone(), params)?;
        block_iterations.push(r.iterations);
        seeds = r.centers.clone();
        for i in 0..c {
            pool.push_row(r.centers.row(i));
            pool_w.push(r.weights[i]);
        }
        blocks += 1;
        start = end;
    }

    // V_final, W_f = WFCM over the pooled weighted centers.
    let pool_w32: Vec<f32> = pool_w.iter().map(|&w| w as f32).collect();
    let final_run = run_fcm(backend, &pool, &pool_w32, seeds, params)?;
    Ok(WfcmpbResult { result: final_run, blocks, block_iterations })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;
    use crate::fcm::seeding::random_records;
    use crate::fcm::{max_center_shift2, NativeBackend};
    use crate::prng::Pcg;

    fn params() -> FcmParams {
        FcmParams { epsilon: 1e-10, ..Default::default() }
    }

    #[test]
    fn matches_full_fcm_on_blobs() {
        let data = blobs(900, 3, 3, 0.2, 1);
        let mut rng = Pcg::new(2);
        let v0 = random_records(&data.features, 3, &mut rng);
        let w = vec![1.0f32; 900];
        let full = run_fcm(&NativeBackend, &data.features, &w, v0.clone(), &params()).unwrap();
        let blocked = wfcmpb(&NativeBackend, &data.features, v0, 300, &params()).unwrap();
        assert_eq!(blocked.blocks, 3);
        // Same blob structure → same centers up to matching/tolerance.
        // Compare via nearest-center distance both ways.
        let a = &full.centers;
        let b = &blocked.result.centers;
        for i in 0..3 {
            let best = (0..3)
                .map(|j| {
                    crate::data::matrix::dist2(a.row(i), b.row(j))
                })
                .fold(f64::INFINITY, f64::min);
            assert!(best < 0.05, "center {i} off by {best}");
        }
    }

    #[test]
    fn single_block_equals_plain_fcm_plus_merge() {
        let data = blobs(200, 2, 2, 0.3, 3);
        let mut rng = Pcg::new(4);
        let v0 = random_records(&data.features, 2, &mut rng);
        let r = wfcmpb(&NativeBackend, &data.features, v0, 500, &params()).unwrap();
        assert_eq!(r.blocks, 1);
        assert!(r.result.converged);
    }

    #[test]
    fn tail_smaller_than_c_is_not_dropped() {
        // 10 records, block 7 → tail of 3 with c=2 is clustered; tail of 1
        // with c=2 goes to the pool directly.
        let data = blobs(15, 2, 2, 0.3, 5);
        let mut rng = Pcg::new(6);
        let v0 = random_records(&data.features, 2, &mut rng);
        let r = wfcmpb(&NativeBackend, &data.features, v0, 7, &params()).unwrap();
        assert!(r.blocks >= 2);
        assert!(r.result.centers.rows() == 2);
    }

    #[test]
    fn warm_start_reduces_block_iterations() {
        // Later blocks should typically converge in fewer iterations than
        // the first (they inherit fitted centers) on iid data.
        let data = blobs(3000, 4, 3, 0.25, 7);
        let mut rng = Pcg::new(8);
        let v0 = random_records(&data.features, 3, &mut rng);
        let r = wfcmpb(&NativeBackend, &data.features, v0, 600, &params()).unwrap();
        let first = r.block_iterations[0];
        let later: f64 = r.block_iterations[1..]
            .iter()
            .map(|&x| x as f64)
            .sum::<f64>()
            / (r.block_iterations.len() - 1) as f64;
        assert!(
            later <= first as f64,
            "warm start didn't help: first={first}, later mean={later}"
        );
    }

    #[test]
    fn deterministic_given_seeds() {
        let data = blobs(400, 3, 2, 0.3, 9);
        let mut rng = Pcg::new(10);
        let v0 = random_records(&data.features, 2, &mut rng);
        let a = wfcmpb(&NativeBackend, &data.features, v0.clone(), 100, &params()).unwrap();
        let b = wfcmpb(&NativeBackend, &data.features, v0, 100, &params()).unwrap();
        assert_eq!(max_center_shift2(&a.result.centers, &b.result.centers), 0.0);
    }
}
