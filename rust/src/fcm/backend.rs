//! The [`KernelBackend`] contract: one object-safe interface owning exact
//! partials, pruned partials, the per-block bound-state layout and the
//! bound maintenance on center shift — for every backend.
//!
//! ## Why the pruning protocol lives here and not in the kernels
//!
//! PR-3 welded the shift-bounded pruning logic into three near-duplicate
//! native kernels (`fcm`/`classic`/`kmeans` each carried its own
//! replay/gather/refresh loop), which meant the session layer's wins died
//! the moment the backend swapped to PJRT. The protocol is actually
//! backend-agnostic: deciding which records replay, replaying their cached
//! contributions, gathering the rest into a compact tile set and
//! scattering the refreshed bounds back is pure host bookkeeping — only
//! the *exact math over the gathered rows* is backend work. So the
//! contract splits there:
//!
//! * backends implement two primitives — [`KernelBackend::exact_partials`]
//!   (one pass of a [`Kernel`] over a block) and
//!   [`KernelBackend::partials_with_bounds`] (the same pass, additionally
//!   emitting the per-row [`BoundRows`] the bounds are rebuilt from);
//! * the full pruning protocol is a *provided* trait method
//!   ([`KernelBackend::pruned_partials`]) driving [`BlockBounds`] — every
//!   backend that can run an exact pass gets shift-bounded pruning for
//!   free, and there is exactly one copy of the bound logic to audit.
//!
//! ## Bound models
//!
//! [`BlockBounds`] maintains one of two models (selected per session via
//! `cluster.bounds`):
//!
//! * **`dmin`** (PR-3): one nearest-center distance per record; a record
//!   replays while `max_j δ_j ≤ tol × d_min`. Cheap (O(1) per-record
//!   check) but a single still-moving center stalls the whole bound.
//! * **`elkan`**: per-record × per-center lower bounds `lb_j` (Elkan-style,
//!   adapted to fuzzy memberships): each center only has to satisfy its
//!   own `δ_j ≤ tol × lb_j`. Since `δ_j ≤ max δ` and `lb_j ≥ d_min`,
//!   every `dmin`-prunable record is `elkan`-prunable — the per-center
//!   model prunes a superset, and keeps pruning through mid-shift
//!   iterations where one center's drift freezes the `d_min` bound. The
//!   per-record check is O(C) and the slab state grows by C·4 B/record
//!   (charged — see [`BlockBounds::bytes`]).
//! * **`hamerly`**: the `elkan` lower bounds plus a Hamerly-style single
//!   bound per record checked *first*: the O(1) `δ_max ≤ tol × d_min`
//!   test prunes the common case without touching the C per-center
//!   bounds, which remain as the exact fallback — the pruned set contains
//!   `elkan`'s while the per-record check usually costs what `dmin`'s
//!   does (the ROADMAP "one-upper-bound tightening" follow-up).
//!
//! For K-Means the bound is not a tolerance but the exact assignment
//! margin: `dmin` uses the classic `2·δ_max ≤ d₂ − d₁` test, `elkan` the
//! per-center generalization `lb_j − δ_j ≥ lb_b + δ_b` for every rival
//! `j`, and `hamerly` the refined single test `δ_b + max_{j≠b} δ_j ≤
//! d₂ − d₁` (sound because every rival satisfies `d_j − δ_j ≥ d₂ −
//! max_{j≠b} δ_j` while the best drifts at most `δ_b`) with the
//! per-center test as fallback — under any of them, the cached assignment
//! (and therefore the record's exact `w_acc`/`v_num` contribution) cannot
//! have changed.
//!
//! `δ_j` accumulates center `j`'s *path length* since the block's last
//! full refresh, which upper-bounds its movement since any later
//! per-record refresh — so mixed passes stay conservative.
//!
//! Underneath all three models sits the optional **quantized pre-pass**
//! (`cluster.quant = i8`, see [`crate::fcm::quant`]): records the shift
//! bound abandons get a second chance from an i8 sidecar's certified
//! distance interval before any exact f32 math runs. The pre-pass only
//! ever *adds* replays, so each model's pruned set with quant on contains
//! its pruned set with quant off.
//!
//! [`BlockBounds`] lives in a session's
//! [`crate::mapreduce::session::StateSlab`], byte-accounted and — via its
//! bitwise [`SlabState::spill`]/[`SlabState::unspill`] codec — spillable
//! to the slab's disk ring instead of being evicted under budget pressure.

use crate::data::matrix::dist2;
use crate::data::Matrix;
use crate::error::Result;
use crate::fcm::native::DIST_EPS;
use crate::fcm::quant::{QuantCenters, QuantSidecar};
use crate::fcm::Partials;
use crate::hdfs::fnv1a;
use crate::mapreduce::session::SlabState;

pub use crate::config::{BoundModel, QuantMode};

/// Which partials pass a backend computes — the dispatch token that
/// replaced the per-variant match arms of the session/baseline layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Fast (Kolen–Hutcheson) FCM, O(C·d) per record.
    FcmFast,
    /// Classic FCM through the **fused** membership evaluation: the
    /// textbook `u_i = 1 / Σ_j (d_i/d_j)^p` computed as `d_i^{-p} / Σ_j
    /// d_j^{-p}` — one reciprocal sum per record, the O(C²) pair loop
    /// skipped (ROADMAP kernel follow-up).
    FcmClassic,
    /// Classic FCM paying the textbook O(C²) pair loop per record — the
    /// compute model of the Mahout-FKM baseline (kept so that model stays
    /// honest) and the property-test oracle of the fused path.
    FcmClassicPair,
    /// Hard K-Means.
    KMeans,
}

impl Kernel {
    pub fn is_kmeans(&self) -> bool {
        matches!(self, Kernel::KMeans)
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Kernel::FcmFast => "fcm-fast",
            Kernel::FcmClassic => "fcm-classic",
            Kernel::FcmClassicPair => "fcm-classic-pair",
            Kernel::KMeans => "kmeans",
        }
    }
}

/// Knobs of one pruned pass.
#[derive(Clone, Copy, Debug)]
pub struct BoundConfig {
    /// Bound model the block state maintains.
    pub model: BoundModel,
    /// Relative distance-perturbation tolerance (≤ 0 disables pruning —
    /// every pass refreshes exactly). For K-Means it only gates whether
    /// pruning runs; the margin test itself is absolute.
    pub tolerance: f64,
    /// Force an exact (bound-refreshing) pass at least every this many
    /// passes — the drift cap.
    pub refresh_every: usize,
    /// Quantized distance pre-pass: records the shift bound abandons get
    /// a second chance from the sidecar's certified interval before the
    /// exact gather (see [`crate::fcm::quant`]).
    pub quant: QuantMode,
}

/// What one pruned pass did — the counters [`KernelBackend::pruned_partials`]
/// returns next to the partials and the session layer folds into
/// `JobStats`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PruneStats {
    /// Records that replayed their cached contribution (any test).
    pub pruned: usize,
    /// Subset of `pruned` admitted by the quantized second-chance test
    /// after the shift bound failed.
    pub quant: usize,
    /// Bytes of the block's quant sidecar (0 with quant off).
    pub sidecar_bytes: u64,
    /// Seconds spent building the sidecar, non-zero only on the one pass
    /// that built it.
    pub sidecar_build_s: f64,
}

/// Per-row outputs of a bound-refreshing exact pass, in gathered-row
/// order. Backends fill these; the protocol scatters them into the
/// sticky [`BlockBounds`]. A real device backend returns these arrays
/// from the lowered kernel; the offline shim marshals them per chunk.
pub struct BoundRows {
    /// Squared distance to every center, (t × C) — the *clamped* values
    /// (≥ the kernel's distance epsilon) the membership math used.
    pub d2: Matrix,
    /// u^m·w contribution per center, (t × C). FCM kernels only (0×0 for
    /// K-Means).
    pub um: Matrix,
    /// Per-row objective contribution.
    pub obj: Vec<f32>,
    /// Nearest center per row. K-Means only (empty for FCM).
    pub best: Vec<u32>,
}

impl BoundRows {
    pub fn for_kernel(kernel: Kernel, t: usize, c: usize) -> Self {
        if kernel.is_kmeans() {
            Self {
                d2: Matrix::zeros(t, c),
                um: Matrix::zeros(0, 0),
                obj: vec![0.0; t],
                best: vec![0; t],
            }
        } else {
            Self {
                d2: Matrix::zeros(t, c),
                um: Matrix::zeros(t, c),
                obj: vec![0.0; t],
                best: Vec::new(),
            }
        }
    }
}

/// Backend executing one pass of per-chunk heavy math — and, through the
/// provided [`Self::pruned_partials`], the whole backend-portable pruning
/// protocol.
pub trait KernelBackend: Send + Sync {
    /// One exact partials pass of `kernel` over a block (`m` is ignored by
    /// [`Kernel::KMeans`]).
    fn exact_partials(&self, kernel: Kernel, x: &Matrix, v: &Matrix, w: &[f32], m: f64)
        -> Result<Partials>;

    /// [`Self::exact_partials`] that additionally fills `rows` with the
    /// per-row bound inputs (distances, contributions, assignments) the
    /// protocol rebuilds [`BlockBounds`] from. `x`/`w`/`rows` are in the
    /// same (gathered) row order; rows with zero weight may carry
    /// arbitrary bound values but must contribute nothing to the partials.
    fn partials_with_bounds(
        &self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
        rows: &mut BoundRows,
    ) -> Result<Partials>;

    /// Human name for reports ("native", "pjrt", "pjrt-shim").
    fn name(&self) -> &'static str;

    /// One pruned pass against the block's sticky `state`: records whose
    /// bound still holds replay their cached contribution, records the
    /// bound abandons may be re-certified by the quantized pre-pass (when
    /// `cfg.quant` enables it), and the rest are gathered and recomputed
    /// exactly through [`Self::partials_with_bounds`]. Returns the
    /// partials and the pass's [`PruneStats`]. Provided generically —
    /// backends only override to opt *out* (e.g. device artifacts without
    /// the bound outputs reset the state and run exactly, so no stale
    /// bound can survive them).
    #[allow(clippy::too_many_arguments)]
    fn pruned_partials(
        &self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
        state: &mut BlockBounds,
        cfg: &BoundConfig,
    ) -> Result<(Partials, PruneStats)> {
        state.pruned_pass(kernel, x, v, w, cfg, &mut |xg: &Matrix, wg: &[f32], rows: &mut BoundRows| {
            self.partials_with_bounds(kernel, xg, v, wg, m, rows)
        })
    }

    /// Fast-FCM (Kolen–Hutcheson) partials, O(C·d) per record.
    fn fcm_partials(&self, x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Result<Partials> {
        self.exact_partials(Kernel::FcmFast, x, v, w, m)
    }

    /// Classic-FCM partials through the fused (pair-loop-free) path.
    fn classic_partials(&self, x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Result<Partials> {
        self.exact_partials(Kernel::FcmClassic, x, v, w, m)
    }

    /// Classic-FCM partials paying the O(C²) pair loop (the Mahout model).
    fn classic_partials_pair(&self, x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Result<Partials> {
        self.exact_partials(Kernel::FcmClassicPair, x, v, w, m)
    }

    /// Hard K-Means partials.
    fn kmeans_partials(&self, x: &Matrix, v: &Matrix, w: &[f32]) -> Result<Partials> {
        self.exact_partials(Kernel::KMeans, x, v, w, 0.0)
    }

    /// Membership rows `u` (n × C) of `x` against centers `v` — the
    /// serving primitive behind [`crate::serve`] (the micro-batched score
    /// service and the bulk ScoreJob). Provided generically from
    /// [`Self::partials_with_bounds`]'s clamped per-center distances, so
    /// every backend that can emit bound rows serves memberships with its
    /// own execution shape (the PJRT shim keeps its padded fixed-row
    /// chunks); backends with a direct kernel override (native). K-Means
    /// rows are the one-hot assignment; FCM rows are the textbook
    /// distribution, identical for every FCM kernel.
    fn score_chunk(
        &self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        m: f64,
        u: &mut Matrix,
    ) -> Result<()> {
        let (n, c) = (x.rows(), v.rows());
        debug_assert_eq!(u.rows(), n);
        debug_assert_eq!(u.cols(), c);
        if n == 0 || c == 0 {
            return Ok(());
        }
        let w = vec![1.0f32; n];
        let mut rows = BoundRows::for_kernel(kernel, n, c);
        self.partials_with_bounds(kernel, x, v, &w, m, &mut rows)?;
        memberships_from_bounds(kernel, &rows, m, u);
        Ok(())
    }
}

/// One record's FCM membership row from *clamped* squared distances —
/// the single copy of the fused formulation `u_i = (dmin/d_i)^p / Σ_j
/// (dmin/d_j)^p` (the dmin normalisation keeps every term ≤ 1, exactly
/// like the kernels) that every serving path evaluates:
/// [`memberships_from_bounds`] here and the tiled
/// `fcm::native::score_rows_native`. The scalar `fcm::native::memberships`
/// deliberately stays an *independent* evaluation (the num form) so it
/// can serve as these paths' test oracle.
pub(crate) fn membership_row_from_d2(d2: &[f64], p: f64, m2: bool, inv: &mut [f64], out: &mut [f32]) {
    let mut dmin = f64::INFINITY;
    for &v in d2 {
        dmin = dmin.min(v);
    }
    let mut s = 0.0f64;
    for (ri, &v) in inv.iter_mut().zip(d2) {
        let r = dmin / v;
        *ri = if m2 { r } else { r.powf(p) };
        s += *ri;
    }
    for (ui, &ri) in out.iter_mut().zip(inv.iter()) {
        *ui = (ri / s) as f32;
    }
}

/// Derive membership rows from a bound-emitting pass's clamped per-center
/// distances: the backend-portable half of the default
/// [`KernelBackend::score_chunk`]. FCM rows go through
/// [`membership_row_from_d2`], K-Means rows are the one-hot assignment.
pub fn memberships_from_bounds(kernel: Kernel, rows: &BoundRows, m: f64, u: &mut Matrix) {
    let (n, c) = (u.rows(), u.cols());
    debug_assert_eq!(rows.d2.rows(), n);
    debug_assert_eq!(rows.d2.cols(), c);
    if kernel.is_kmeans() {
        for k in 0..n {
            let urow = u.row_mut(k);
            urow.fill(0.0);
            urow[rows.best[k] as usize] = 1.0;
        }
        return;
    }
    let p = 1.0 / (m - 1.0);
    let m2 = m == 2.0;
    let mut inv = vec![0.0f64; c];
    let mut d2v = vec![0.0f64; c];
    for k in 0..n {
        for (dv, &d2) in d2v.iter_mut().zip(rows.d2.row(k)) {
            *dv = d2 as f64;
        }
        membership_row_from_d2(&d2v, p, m2, &mut inv, u.row_mut(k));
    }
}

/// Per-block sticky bound state — layout owned here, maintained by the
/// protocol, persisted in the session's `StateSlab` between iterations
/// (and across its disk spill ring, bitwise).
#[derive(Clone, Debug)]
pub struct BlockBounds {
    /// Bound model the cached arrays belong to.
    model: BoundModel,
    /// Kernel the cached state belongs to (a different kernel refreshes).
    kernel: Option<Kernel>,
    /// Centers seen by the most recent pass (for shift accumulation).
    centers_prev: Matrix,
    /// Per-center path length accumulated since the last full refresh.
    delta: Vec<f64>,
    /// Per-record nearest-center distance — FCM `dmin` model.
    d_min: Vec<f32>,
    /// Per-record runner-up margin `d₂ − d₁` — K-Means (both models; the
    /// whole-block K-Means bound reads its min).
    margin: Vec<f32>,
    /// Per-record × per-center lower bounds — `elkan` model, (n × C).
    lb: Matrix,
    /// Per-record cached contribution u^m·w per center — FCM, (n × C).
    um: Matrix,
    /// Per-record cached objective contribution.
    obj: Vec<f32>,
    /// Per-record cached assignment — K-Means.
    best: Vec<u32>,
    /// Block minima of the per-record bounds (whole-block prune tests).
    d_min_block: f32,
    margin_block: f32,
    lb_block: Vec<f32>,
    /// The block's latest partials (whole-block replay reuses these).
    partials: Option<Partials>,
    /// Live (non-zero-weight) records at the last refresh — the
    /// whole-block replayed count. (Pruning assumes per-record weights
    /// are stable across the session, which the session loop's uniform
    /// weights guarantee.)
    live: usize,
    /// Passes since the last full refresh.
    stale_iters: usize,
    /// Block payload bytes (n·d·4) — the modelled read an exact recompute
    /// of this state pays, the reread-vs-recompute crossover input of the
    /// slab's spill policy.
    block_payload_bytes: u64,
    /// Quant mode the cached arrays belong to (a mode switch refreshes —
    /// the lb layout differs and the second-chance test must not consult
    /// bounds a quant-off pass maintained, or vice versa).
    quant: QuantMode,
    /// The block's i8 quantization, built lazily on the first
    /// quant-enabled pass. Depends only on the block payload: it survives
    /// bound refreshes and spills with the rest of the state.
    sidecar: Option<QuantSidecar>,
}

impl Default for BlockBounds {
    fn default() -> Self {
        Self {
            model: BoundModel::Elkan,
            kernel: None,
            centers_prev: Matrix::zeros(0, 0),
            delta: Vec::new(),
            d_min: Vec::new(),
            margin: Vec::new(),
            lb: Matrix::zeros(0, 0),
            um: Matrix::zeros(0, 0),
            obj: Vec::new(),
            best: Vec::new(),
            d_min_block: f32::INFINITY,
            margin_block: f32::INFINITY,
            lb_block: Vec::new(),
            partials: None,
            live: 0,
            stale_iters: 0,
            block_payload_bytes: 0,
            quant: QuantMode::Off,
            sidecar: None,
        }
    }
}

/// Hoisted per-pass shift thresholds of the record-level bound tests.
struct ShiftInfo {
    /// δ_max / tol — the FCM single-bound test in distance units.
    thr_dmin: f64,
    /// 2 · δ_max — the K-Means `dmin` margin test.
    two_delta: f64,
    /// Largest per-center accumulated shift, the center attaining it, and
    /// the runner-up (the K-Means `hamerly` test's `max_{j≠best} δ_j`).
    delta_top: f64,
    delta_top_idx: usize,
    delta_second: f64,
}

impl ShiftInfo {
    fn new(delta: &[f64], delta_max: f64, tol: f64) -> Self {
        let (mut top, mut second, mut idx) = (0.0f64, 0.0f64, 0usize);
        for (j, &d) in delta.iter().enumerate() {
            if d > top {
                second = top;
                top = d;
                idx = j;
            } else if d > second {
                second = d;
            }
        }
        Self {
            thr_dmin: delta_max / tol,
            two_delta: 2.0 * delta_max,
            delta_top: top,
            delta_top_idx: idx,
            delta_second: second,
        }
    }

    /// `max_{j≠b} δ_j` in O(1).
    fn max_other(&self, b: usize) -> f64 {
        if b == self.delta_top_idx {
            self.delta_second
        } else {
            self.delta_top
        }
    }
}

/// Running block minima of one pass (replayed records fold their cached
/// bounds, recomputed records their fresh ones).
struct Mins {
    d_min: f32,
    margin: f32,
    lb: Vec<f32>,
}

impl Mins {
    fn new(kernel: Kernel, keep_lb: bool, c: usize) -> Self {
        let lb = if keep_lb && !kernel.is_kmeans() {
            vec![f32::INFINITY; c]
        } else {
            Vec::new()
        };
        Self { d_min: f32::INFINITY, margin: f32::INFINITY, lb }
    }

    fn fold_cached(&mut self, st: &BlockBounds, kernel: Kernel, k: usize) {
        if kernel.is_kmeans() {
            self.margin = self.margin.min(st.margin[k]);
            return;
        }
        if st.keeps_lb_eff() {
            for (m, &lb) in self.lb.iter_mut().zip(st.lb.row(k)) {
                *m = (*m).min(lb);
            }
        }
        if st.model.keeps_dmin() {
            self.d_min = self.d_min.min(st.d_min[k]);
        }
    }

    fn store(self, st: &mut BlockBounds) {
        st.d_min_block = self.d_min;
        st.margin_block = self.margin;
        st.lb_block = self.lb;
    }
}

impl BlockBounds {
    /// Drop every cached bound: the next pass is exact and refreshing.
    pub fn reset(&mut self) {
        *self = Self::default();
    }

    /// Whether any bounds are currently cached.
    pub fn is_fresh(&self) -> bool {
        self.partials.is_some()
    }

    /// Byte footprint for slab accounting. Charges **every** per-record
    /// array — including the `elkan` model's per-center lower bounds
    /// (C·4 B/record on top of the `dmin` layout's flat 8 B/record) and
    /// the quant sidecar (d B/record of i8 codes plus the scales), which
    /// the slab sizing rules must budget for (see `examples/scale_susy`).
    pub fn bytes(&self) -> u64 {
        let f32s = self.d_min.len()
            + self.margin.len()
            + self.obj.len()
            + self.lb_block.len()
            + self.um.rows() * self.um.cols()
            + self.lb.rows() * self.lb.cols()
            + self.centers_prev.rows() * self.centers_prev.cols();
        let partials = self.partials.as_ref().map(Partials::encoded_bytes).unwrap_or(0);
        let sidecar = self.sidecar.as_ref().map(QuantSidecar::bytes).unwrap_or(0);
        (f32s * 4 + self.delta.len() * 8 + self.best.len() * 4) as u64 + partials + sidecar
    }

    /// Sidecar bytes currently held (0 without one) — surfaced through
    /// [`PruneStats`] into the session's `JobStats`.
    pub fn quant_sidecar_bytes(&self) -> u64 {
        self.sidecar.as_ref().map(QuantSidecar::bytes).unwrap_or(0)
    }

    /// Whether the cached layout carries the per-record × per-center
    /// lower bounds. The quant second chance certifies *against* those
    /// refresh-time distances, so enabling quant widens every model to
    /// the lb-carrying layout (dmin included — byte-accounted above).
    fn keeps_lb_eff(&self) -> bool {
        self.model.keeps_lb() || self.quant.enabled()
    }

    /// Whether the cached state can bound a pass of `kernel` under `cfg`.
    fn usable(&self, kernel: Kernel, n: usize, c: usize, d: usize, cfg: &BoundConfig) -> bool {
        let base = cfg.tolerance > 0.0
            && c > 0
            && self.kernel == Some(kernel)
            && self.model == cfg.model
            && self.quant == cfg.quant
            && self.partials.is_some()
            && self.stale_iters < cfg.refresh_every.max(1)
            && self.centers_prev.rows() == c
            && self.centers_prev.cols() == d
            && self.delta.len() == c
            && self.obj.len() == n;
        if !base {
            return false;
        }
        if cfg.quant.enabled() && !self.sidecar.as_ref().map_or(false, |s| s.matches(n, d)) {
            return false;
        }
        let lb_ok = self.lb.rows() == n && self.lb.cols() == c;
        // Quant widens every model to the lb-carrying layout: the second
        // chance certifies against the refresh-time per-center distances.
        let lb_need = cfg.model.keeps_lb() || cfg.quant.enabled();
        if kernel.is_kmeans() {
            let km = self.best.len() == n && self.margin.len() == n;
            km && (!lb_need || lb_ok)
        } else {
            let fcm = self.um.rows() == n && self.um.cols() == c;
            fcm && (!lb_need || (lb_ok && self.lb_block.len() == c))
                && (!cfg.model.keeps_dmin() || self.d_min.len() == n)
        }
    }

    /// Fold the centers' movement since the previous pass into the
    /// per-center accumulated path lengths; returns the largest.
    fn accumulate_shift(&mut self, v: &Matrix) -> f64 {
        let mut worst = 0.0f64;
        for j in 0..v.rows() {
            let step = dist2(self.centers_prev.row(j), v.row(j)).sqrt();
            self.delta[j] += step;
            worst = worst.max(self.delta[j]);
        }
        self.centers_prev = v.clone();
        worst
    }

    /// Whole-block bound: every live record's own test is implied, so the
    /// cached block partials replay without touching a record.
    fn block_prunable(&self, kernel: Kernel, delta_max: f64, tol: f64) -> bool {
        if kernel.is_kmeans() {
            return 2.0 * delta_max <= self.margin_block as f64;
        }
        let dmin_ok = |st: &Self| delta_max <= tol * st.d_min_block as f64;
        let lb_ok = |st: &Self| {
            st.lb_block.iter().zip(&st.delta).all(|(&lb, &dj)| dj <= tol * lb as f64)
        };
        match self.model {
            BoundModel::DMin => dmin_ok(self),
            BoundModel::Elkan => lb_ok(self),
            BoundModel::Hamerly => dmin_ok(self) || lb_ok(self),
        }
    }

    /// The elkan per-center FCM test for record `k`.
    fn elkan_fcm_ok(&self, k: usize, tol: f64) -> bool {
        self.lb.row(k).iter().zip(&self.delta).all(|(&lb, &dj)| dj <= tol * lb as f64)
    }

    /// The elkan per-center K-Means margin test for record `k`.
    fn elkan_kmeans_ok(&self, k: usize) -> bool {
        let lbr = self.lb.row(k);
        let b = self.best[k] as usize;
        let rival_floor = lbr[b] as f64 + self.delta[b];
        lbr.iter()
            .zip(&self.delta)
            .enumerate()
            .all(|(j, (&lb, &dj))| j == b || lb as f64 - dj >= rival_floor)
    }

    /// Per-record bound test, against the pass's hoisted [`ShiftInfo`].
    fn record_prunable(&self, kernel: Kernel, k: usize, tol: f64, shift: &ShiftInfo) -> bool {
        if kernel.is_kmeans() {
            match self.model {
                BoundModel::DMin => shift.two_delta <= self.margin[k] as f64,
                BoundModel::Elkan => self.elkan_kmeans_ok(k),
                BoundModel::Hamerly => {
                    // Hamerly fast test: the best center drifts at most
                    // δ_b while every rival keeps d_j − δ_j ≥ d₂ −
                    // max_{j≠b} δ_j — one comparison in the common case.
                    let b = self.best[k] as usize;
                    self.delta[b] + shift.max_other(b) <= self.margin[k] as f64
                        || self.elkan_kmeans_ok(k)
                }
            }
        } else {
            match self.model {
                BoundModel::DMin => self.d_min[k] as f64 >= shift.thr_dmin,
                BoundModel::Elkan => self.elkan_fcm_ok(k, tol),
                BoundModel::Hamerly => {
                    self.d_min[k] as f64 >= shift.thr_dmin || self.elkan_fcm_ok(k, tol)
                }
            }
        }
    }

    /// Replay record `k`'s cached contribution into `out` (no distance
    /// pass, no powf). For K-Means the replayed `w_acc`/`v_num` terms are
    /// *exact* under the margin test; only the objective term is stale.
    fn replay(&self, kernel: Kernel, k: usize, x: &Matrix, w: &[f32], out: &mut Partials) {
        let row = x.row(k);
        if kernel.is_kmeans() {
            let wk = w[k] as f64;
            let best = self.best[k] as usize;
            out.w_acc[best] += wk;
            out.objective += self.obj[k] as f64;
            let vrow = out.v_num.row_mut(best);
            for (j, val) in vrow.iter_mut().enumerate() {
                *val += (wk * row[j] as f64) as f32;
            }
        } else {
            let um_row = self.um.row(k);
            for (i, &u) in um_row.iter().enumerate() {
                out.w_acc[i] += u as f64;
                let vrow = out.v_num.row_mut(i);
                for (val, &xj) in vrow.iter_mut().zip(row) {
                    *val += u * xj;
                }
            }
            out.objective += self.obj[k] as f64;
        }
    }

    /// Scatter one gathered pass's [`BoundRows`] back into the per-record
    /// state, folding fresh block minima.
    fn scatter(&mut self, kernel: Kernel, idx: &[usize], rows: &BoundRows, mins: &mut Mins) {
        let keeps_lb = self.keeps_lb_eff();
        let keeps_dmin = self.model.keeps_dmin();
        for (r, &k) in idx.iter().enumerate() {
            self.obj[k] = rows.obj[r];
            let d2r = rows.d2.row(r);
            if kernel.is_kmeans() {
                let b = rows.best[r] as usize;
                self.best[k] = rows.best[r];
                let best_d = d2r[b] as f64;
                let mut second = f64::INFINITY;
                for (j, &d2) in d2r.iter().enumerate() {
                    if j != b {
                        second = second.min(d2 as f64);
                    }
                }
                // C = 1: the assignment can never change.
                let margin = if second.is_finite() {
                    (second.sqrt() - best_d.sqrt()) as f32
                } else {
                    f32::INFINITY
                };
                self.margin[k] = margin;
                mins.margin = mins.margin.min(margin);
                if keeps_lb {
                    for (lb, &d2) in self.lb.row_mut(k).iter_mut().zip(d2r) {
                        *lb = (d2 as f64).sqrt() as f32;
                    }
                }
            } else {
                self.um.row_mut(k).copy_from_slice(rows.um.row(r));
                let mut dmin = f64::INFINITY;
                if keeps_lb {
                    for ((lb, m), &d2) in
                        self.lb.row_mut(k).iter_mut().zip(mins.lb.iter_mut()).zip(d2r)
                    {
                        let de = (d2 as f64).sqrt() as f32;
                        *lb = de;
                        *m = (*m).min(de);
                        dmin = dmin.min(d2 as f64);
                    }
                } else {
                    for &d2 in d2r {
                        dmin = dmin.min(d2 as f64);
                    }
                }
                if keeps_dmin {
                    let de = dmin.sqrt() as f32;
                    self.d_min[k] = de;
                    mins.d_min = mins.d_min.min(de);
                }
            }
        }
    }

    /// Full exact pass that (re)builds every cached bound — the fallback
    /// for empty/mismatched state, disabled pruning, and the periodic
    /// refresh. `f` runs the backend's bound-emitting exact pass over the
    /// gathered live rows.
    pub fn refresh<F>(
        &mut self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        model: BoundModel,
        quant: QuantMode,
        f: &mut F,
    ) -> Result<Partials>
    where
        F: FnMut(&Matrix, &[f32], &mut BoundRows) -> Result<Partials>,
    {
        let (n, c, d) = (x.rows(), v.rows(), v.cols());
        debug_assert_eq!(n, w.len());
        self.kernel = Some(kernel);
        self.model = model;
        self.quant = quant;
        if !quant.enabled() {
            self.sidecar = None;
        }
        self.centers_prev = v.clone();
        self.delta = vec![0.0; c];
        self.stale_iters = 0;
        self.obj = vec![0.0; n];
        self.block_payload_bytes = (n * d * 4) as u64;
        let keep_lb = self.keeps_lb_eff();
        if kernel.is_kmeans() {
            self.um = Matrix::zeros(0, 0);
            self.d_min = Vec::new();
            self.best = vec![0; n];
            self.margin = vec![f32::INFINITY; n];
        } else {
            self.um = Matrix::zeros(n, c);
            self.best = Vec::new();
            self.margin = Vec::new();
            self.d_min = if model.keeps_dmin() { vec![f32::INFINITY; n] } else { Vec::new() };
        }
        self.lb = if keep_lb {
            let mut lb = Matrix::zeros(n, c);
            lb.as_mut_slice().fill(f32::INFINITY);
            lb
        } else {
            Matrix::zeros(0, 0)
        };
        self.live = w.iter().filter(|&&wk| wk != 0.0).count();
        let mut out = Partials::zeros(c, d);
        let mut mins = Mins::new(kernel, keep_lb, c);
        if c > 0 && self.live > 0 {
            if self.live == n {
                // Uniform-weight fast path: no gather copy.
                let idx: Vec<usize> = (0..n).collect();
                let mut rows = BoundRows::for_kernel(kernel, n, c);
                out = f(x, w, &mut rows)?;
                self.scatter(kernel, &idx, &rows, &mut mins);
            } else {
                let mut idx = Vec::with_capacity(self.live);
                let mut buf: Vec<f32> = Vec::with_capacity(self.live * d);
                for k in 0..n {
                    if w[k] != 0.0 {
                        idx.push(k);
                        buf.extend_from_slice(x.row(k));
                    }
                }
                let xg = Matrix::from_vec(buf, idx.len(), d);
                let wg: Vec<f32> = idx.iter().map(|&k| w[k]).collect();
                let mut rows = BoundRows::for_kernel(kernel, idx.len(), c);
                out = f(&xg, &wg, &mut rows)?;
                self.scatter(kernel, &idx, &rows, &mut mins);
            }
        }
        mins.store(self);
        self.partials = Some(out.clone());
        Ok(out)
    }

    /// Quantized second chance for record `k` after the shift bound
    /// failed: the sidecar's certified interval `[lo_j, hi_j]` on the
    /// *current* distance either re-certifies the replay contract per
    /// center (FCM: every distance provably within `tol` of its cached
    /// refresh-time value, the same perturbation contract as the elkan
    /// test) or eliminates every rival exactly (K-Means: `lo_j > hi_b`
    /// means the assignment provably didn't change). Memoryless in δ —
    /// this is where path-length overcharge gets repaid.
    fn quant_replayable(
        &self,
        kernel: Kernel,
        k: usize,
        tol: f64,
        qc: &QuantCenters,
        d2: &mut [f64],
        err: &mut [f64],
    ) -> bool {
        let sidecar = self.sidecar.as_ref().expect("quant pass holds a sidecar");
        sidecar.row_distances(k, qc, d2, err);
        let lbr = self.lb.row(k);
        if kernel.is_kmeans() {
            let b = self.best[k] as usize;
            let hi_b = (d2[b] + err[b]).max(DIST_EPS).sqrt();
            let rival_floor = lbr[b] as f64 + self.delta[b];
            for j in 0..d2.len() {
                if j == b {
                    continue;
                }
                // Per-rival: the elkan shift test or a certified strict
                // separation right now (strict, so argmin tie-breaks
                // can't flip the assignment either way).
                if lbr[j] as f64 - self.delta[j] >= rival_floor {
                    continue;
                }
                if (d2[j] - err[j]).max(DIST_EPS).sqrt() > hi_b {
                    continue;
                }
                return false;
            }
            true
        } else {
            for (j, (&lb, &dj)) in lbr.iter().zip(&self.delta).enumerate() {
                let lb = lb as f64;
                if dj <= tol * lb {
                    continue;
                }
                let lo = (d2[j] - err[j]).max(DIST_EPS).sqrt();
                let hi = (d2[j] + err[j]).max(DIST_EPS).sqrt();
                if hi <= (1.0 + tol) * lb && lo >= (1.0 - tol) * lb {
                    continue;
                }
                return false;
            }
            true
        }
    }

    /// One pruned pass (the protocol behind
    /// [`KernelBackend::pruned_partials`]): whole-block replay when the
    /// block bound holds, otherwise per-record replay (shift bound, then
    /// the quantized second chance) + a gathered exact recompute of the
    /// rest through `f`.
    pub fn pruned_pass<F>(
        &mut self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        cfg: &BoundConfig,
        f: &mut F,
    ) -> Result<(Partials, PruneStats)>
    where
        F: FnMut(&Matrix, &[f32], &mut BoundRows) -> Result<Partials>,
    {
        let (n, c, d) = (x.rows(), v.rows(), v.cols());
        debug_assert_eq!(n, w.len());
        let mut stats = PruneStats::default();
        // Lazy one-time sidecar: built on the block's first quant-enabled
        // touch (before the usability check — an unusable state still
        // keeps its sidecar through the refresh).
        if cfg.quant.enabled() {
            if !self.sidecar.as_ref().map_or(false, |s| s.matches(n, d)) {
                let t0 = std::time::Instant::now();
                self.sidecar = Some(QuantSidecar::build(x));
                stats.sidecar_build_s = t0.elapsed().as_secs_f64();
            }
            stats.sidecar_bytes = self.quant_sidecar_bytes();
        }
        if !self.usable(kernel, n, c, d, cfg) {
            let p = self.refresh(kernel, x, v, w, cfg.model, cfg.quant, f)?;
            return Ok((p, stats));
        }
        self.stale_iters += 1;
        let delta_max = self.accumulate_shift(v);
        let tol = cfg.tolerance;
        if self.block_prunable(kernel, delta_max, tol) {
            let p = self.partials.clone().expect("usable implies cached partials");
            stats.pruned = self.live;
            return Ok((p, stats));
        }
        let shift = ShiftInfo::new(&self.delta, delta_max, tol);
        let qc = if cfg.quant.enabled() {
            self.sidecar.as_ref().map(|s| s.prep_centers(v))
        } else {
            None
        };
        let mut d2q = vec![0.0f64; c];
        let mut errq = vec![0.0f64; c];
        let mut out = Partials::zeros(c, d);
        let mut idx: Vec<usize> = Vec::new();
        let mut buf: Vec<f32> = Vec::new();
        let mut mins = Mins::new(kernel, self.keeps_lb_eff(), c);
        for k in 0..n {
            if w[k] == 0.0 {
                continue; // padding contract
            }
            let replayable = if self.record_prunable(kernel, k, tol, &shift) {
                true
            } else if let Some(qc) = &qc {
                let ok = self.quant_replayable(kernel, k, tol, qc, &mut d2q, &mut errq);
                stats.quant += ok as usize;
                ok
            } else {
                false
            };
            if replayable {
                self.replay(kernel, k, x, w, &mut out);
                mins.fold_cached(self, kernel, k);
                stats.pruned += 1;
            } else {
                idx.push(k);
                buf.extend_from_slice(x.row(k));
            }
        }
        if !idx.is_empty() {
            let xg = Matrix::from_vec(buf, idx.len(), d);
            let wg: Vec<f32> = idx.iter().map(|&k| w[k]).collect();
            let mut rows = BoundRows::for_kernel(kernel, idx.len(), c);
            let fresh = f(&xg, &wg, &mut rows)?;
            out.merge(&fresh);
            self.scatter(kernel, &idx, &rows, &mut mins);
        }
        mins.store(self);
        self.partials = Some(out.clone());
        Ok((out, stats))
    }
}

// ---------------------------------------------------------------------------
// Bitwise LE codec primitives — shared by the slab's disk-ring spill images
// here and the persisted model bundles of `crate::serve::bundle` (the same
// checksummed write/read discipline, crate-internal).
// ---------------------------------------------------------------------------

const SPILL_MAGIC: u32 = 0xB16F_5AB1;
/// v2 appended the quant mode tag + optional sidecar section. Old images
/// simply fail to decode, which the slab answers with an exact refresh —
/// sound, and the ring never persists across sessions anyway.
const SPILL_VERSION: u8 = 2;

pub(crate) fn put_u8(b: &mut Vec<u8>, v: u8) {
    b.push(v);
}

pub(crate) fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f32(b: &mut Vec<u8>, v: f32) {
    b.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f64(b: &mut Vec<u8>, v: f64) {
    b.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f32s(b: &mut Vec<u8>, vs: &[f32]) {
    put_u32(b, vs.len() as u32);
    for &v in vs {
        put_f32(b, v);
    }
}

pub(crate) fn put_f64s(b: &mut Vec<u8>, vs: &[f64]) {
    put_u32(b, vs.len() as u32);
    for &v in vs {
        put_f64(b, v);
    }
}

pub(crate) fn put_u32s(b: &mut Vec<u8>, vs: &[u32]) {
    put_u32(b, vs.len() as u32);
    for &v in vs {
        put_u32(b, v);
    }
}

pub(crate) fn put_matrix(b: &mut Vec<u8>, m: &Matrix) {
    put_u32(b, m.rows() as u32);
    put_u32(b, m.cols() as u32);
    for &v in m.as_slice() {
        put_f32(b, v);
    }
}

/// Length-prefixed byte blob (utf-8 names in model bundles).
pub(crate) fn put_blob(b: &mut Vec<u8>, bytes: &[u8]) {
    put_u32(b, bytes.len() as u32);
    b.extend_from_slice(bytes);
}

/// Bounds-checked little-endian reader over a codec image.
pub(crate) struct Cur<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> Cur<'a> {
    pub(crate) fn new(b: &'a [u8]) -> Self {
        Self { b, p: 0 }
    }

    pub(crate) fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.p.checked_add(n)?;
        if end > self.b.len() {
            return None;
        }
        let s = &self.b[self.p..end];
        self.p = end;
        Some(s)
    }

    pub(crate) fn u8(&mut self) -> Option<u8> {
        Some(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().ok()?))
    }

    pub(crate) fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }

    pub(crate) fn f32(&mut self) -> Option<f32> {
        Some(f32::from_le_bytes(self.take(4)?.try_into().ok()?))
    }

    pub(crate) fn f64(&mut self) -> Option<f64> {
        Some(f64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }

    pub(crate) fn f32s(&mut self) -> Option<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(4)?)?;
        Some(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub(crate) fn f64s(&mut self) -> Option<Vec<f64>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(8)?)?;
        Some(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub(crate) fn u32s(&mut self) -> Option<Vec<u32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(4)?)?;
        Some(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub(crate) fn matrix(&mut self) -> Option<Matrix> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let len = rows.checked_mul(cols)?;
        let raw = self.take(len.checked_mul(4)?)?;
        let data: Vec<f32> =
            raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
        Some(Matrix::from_vec(data, rows, cols))
    }

    pub(crate) fn blob(&mut self) -> Option<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    pub(crate) fn done(&self) -> bool {
        self.p == self.b.len()
    }
}

fn kernel_tag(k: Option<Kernel>) -> u8 {
    match k {
        None => 0,
        Some(Kernel::FcmFast) => 1,
        Some(Kernel::FcmClassic) => 2,
        Some(Kernel::FcmClassicPair) => 3,
        Some(Kernel::KMeans) => 4,
    }
}

fn kernel_from_tag(t: u8) -> Option<Option<Kernel>> {
    Some(match t {
        0 => None,
        1 => Some(Kernel::FcmFast),
        2 => Some(Kernel::FcmClassic),
        3 => Some(Kernel::FcmClassicPair),
        4 => Some(Kernel::KMeans),
        _ => return None,
    })
}

impl SlabState for BlockBounds {
    fn slab_bytes(&self) -> u64 {
        self.bytes()
    }

    fn recompute_bytes(&self) -> u64 {
        self.block_payload_bytes
    }

    /// Bitwise serialisation: every f32/f64 travels as its exact LE bit
    /// pattern, so a spill → reload roundtrip reproduces the state — and
    /// therefore every later pruning decision and replayed contribution —
    /// identically (pinned by `prop_invariants` and the streaming twin).
    fn spill(&self) -> Option<Vec<u8>> {
        let mut b = Vec::with_capacity(self.bytes() as usize + 128);
        put_u32(&mut b, SPILL_MAGIC);
        put_u8(&mut b, SPILL_VERSION);
        put_u8(&mut b, match self.model {
            BoundModel::DMin => 0,
            BoundModel::Elkan => 1,
            BoundModel::Hamerly => 2,
        });
        put_u8(&mut b, kernel_tag(self.kernel));
        put_matrix(&mut b, &self.centers_prev);
        put_f64s(&mut b, &self.delta);
        put_f32s(&mut b, &self.d_min);
        put_f32s(&mut b, &self.margin);
        put_matrix(&mut b, &self.lb);
        put_matrix(&mut b, &self.um);
        put_f32s(&mut b, &self.obj);
        put_u32s(&mut b, &self.best);
        put_f32(&mut b, self.d_min_block);
        put_f32(&mut b, self.margin_block);
        put_f32s(&mut b, &self.lb_block);
        match &self.partials {
            None => put_u8(&mut b, 0),
            Some(p) => {
                put_u8(&mut b, 1);
                put_matrix(&mut b, &p.v_num);
                put_f64s(&mut b, &p.w_acc);
                put_f64(&mut b, p.objective);
            }
        }
        put_u64(&mut b, self.live as u64);
        put_u64(&mut b, self.stale_iters as u64);
        put_u64(&mut b, self.block_payload_bytes);
        put_u8(&mut b, match self.quant {
            QuantMode::Off => 0,
            QuantMode::I8 => 1,
        });
        match &self.sidecar {
            None => put_u8(&mut b, 0),
            Some(s) => {
                put_u8(&mut b, 1);
                s.encode(&mut b);
            }
        }
        // FNV-1a trailer, same discipline as the block codec: a corrupt
        // slot file must fail to decode (the block then refreshes exactly)
        // rather than replay corrupted bounds into the partials.
        let sum = fnv1a(&b);
        put_u64(&mut b, sum);
        Some(b)
    }

    fn unspill(bytes: &[u8]) -> Option<Self> {
        if bytes.len() < 8 {
            return None;
        }
        let (payload, trailer) = bytes.split_at(bytes.len() - 8);
        if fnv1a(payload) != u64::from_le_bytes(trailer.try_into().ok()?) {
            return None;
        }
        let mut c = Cur::new(payload);
        if c.u32()? != SPILL_MAGIC || c.u8()? != SPILL_VERSION {
            return None;
        }
        let model = match c.u8()? {
            0 => BoundModel::DMin,
            1 => BoundModel::Elkan,
            2 => BoundModel::Hamerly,
            _ => return None,
        };
        let kernel = kernel_from_tag(c.u8()?)?;
        let centers_prev = c.matrix()?;
        let delta = c.f64s()?;
        let d_min = c.f32s()?;
        let margin = c.f32s()?;
        let lb = c.matrix()?;
        let um = c.matrix()?;
        let obj = c.f32s()?;
        let best = c.u32s()?;
        let d_min_block = c.f32()?;
        let margin_block = c.f32()?;
        let lb_block = c.f32s()?;
        let partials = match c.u8()? {
            0 => None,
            1 => {
                let v_num = c.matrix()?;
                let w_acc = c.f64s()?;
                let objective = c.f64()?;
                Some(Partials { v_num, w_acc, objective })
            }
            _ => return None,
        };
        let live = c.u64()? as usize;
        let stale_iters = c.u64()? as usize;
        let block_payload_bytes = c.u64()?;
        let quant = match c.u8()? {
            0 => QuantMode::Off,
            1 => QuantMode::I8,
            _ => return None,
        };
        let sidecar = match c.u8()? {
            0 => None,
            1 => Some(QuantSidecar::decode(&mut c)?),
            _ => return None,
        };
        if !c.done() {
            return None;
        }
        Some(Self {
            model,
            kernel,
            centers_prev,
            delta,
            d_min,
            margin,
            lb,
            um,
            obj,
            best,
            d_min_block,
            margin_block,
            lb_block,
            partials,
            live,
            stale_iters,
            block_payload_bytes,
            quant,
            sidecar,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fcm::native::{classic_partials_native, fcm_partials_native, kmeans_partials_native};
    use crate::fcm::NativeBackend;
    use crate::prng::Pcg;

    fn rand_case(n: usize, d: usize, c: usize, seed: u64) -> (Matrix, Matrix, Vec<f32>) {
        let mut rng = Pcg::new(seed);
        let mut x = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                x.set(i, j, rng.normal() as f32);
            }
        }
        let mut v = Matrix::zeros(c, d);
        for i in 0..c {
            for j in 0..d {
                v.set(i, j, rng.normal() as f32);
            }
        }
        let w = (0..n).map(|_| rng.next_f32() + 0.1).collect();
        (x, v, w)
    }

    fn cfg(model: BoundModel) -> BoundConfig {
        BoundConfig { model, tolerance: 1e-2, refresh_every: 8, quant: QuantMode::Off }
    }

    fn cfg_q(model: BoundModel, tolerance: f64) -> BoundConfig {
        BoundConfig { model, tolerance, refresh_every: 8, quant: QuantMode::I8 }
    }

    #[test]
    fn pruned_first_pass_is_exact_refresh() {
        let (x, v, w) = rand_case(120, 5, 4, 41);
        for model in [BoundModel::DMin, BoundModel::Elkan] {
            for m in [1.4, 2.0] {
                let mut state = BlockBounds::default();
                let (p, stats) = NativeBackend
                    .pruned_partials(Kernel::FcmFast, &x, &v, &w, m, &mut state, &cfg(model))
                    .unwrap();
                assert_eq!(stats.pruned, 0, "first pass must refresh, not prune");
                assert!(state.is_fresh());
                let exact = fcm_partials_native(&x, &v, &w, m);
                for (a, b) in p.w_acc.iter().zip(&exact.w_acc) {
                    assert!((a - b).abs() <= 1e-6 + 1e-4 * b.abs(), "{model:?} m={m}: {a} vs {b}");
                }
                let rel = (p.objective - exact.objective).abs() / exact.objective.max(1e-9);
                assert!(rel < 1e-4, "{model:?} m={m}: objective rel {rel}");
            }
        }
    }

    #[test]
    fn unmoved_centers_prune_whole_block() {
        for model in [BoundModel::DMin, BoundModel::Elkan, BoundModel::Hamerly] {
            let (x, v, w) = rand_case(100, 4, 3, 42);
            let mut state = BlockBounds::default();
            let (first, _) = NativeBackend
                .pruned_partials(Kernel::FcmFast, &x, &v, &w, 2.0, &mut state, &cfg(model))
                .unwrap();
            // Same centers again: zero shift → whole block served from cache.
            let (second, stats) = NativeBackend
                .pruned_partials(Kernel::FcmFast, &x, &v, &w, 2.0, &mut state, &cfg(model))
                .unwrap();
            assert_eq!(stats.pruned, 100, "{model:?}");
            assert_eq!(first.w_acc, second.w_acc);
            assert_eq!(first.v_num.as_slice(), second.v_num.as_slice());
            assert_eq!(first.objective, second.objective);
        }
    }

    #[test]
    fn refresh_cap_forces_exact_pass() {
        let (x, v, w) = rand_case(80, 3, 3, 43);
        let cfg = BoundConfig {
            model: BoundModel::Elkan,
            tolerance: 1e-2,
            refresh_every: 2,
            quant: QuantMode::Off,
        };
        let mut state = BlockBounds::default();
        let run = |st: &mut BlockBounds| {
            NativeBackend
                .pruned_partials(Kernel::FcmFast, &x, &v, &w, 2.0, st, &cfg)
                .unwrap()
                .1
                .pruned
        };
        run(&mut state);
        assert_eq!(run(&mut state), 80, "within the cap the unmoved block prunes");
        assert_eq!(run(&mut state), 80);
        // stale_iters hit the cap: next pass must be a refresh.
        assert_eq!(run(&mut state), 0, "refresh_every must force an exact pass");
    }

    #[test]
    fn zero_tolerance_disables_pruning() {
        let (x, v, w) = rand_case(64, 3, 3, 44);
        let cfg = BoundConfig {
            model: BoundModel::Elkan,
            tolerance: 0.0,
            refresh_every: 4,
            quant: QuantMode::Off,
        };
        let mut state = BlockBounds::default();
        for _ in 0..3 {
            let (_, stats) = NativeBackend
                .pruned_partials(Kernel::FcmFast, &x, &v, &w, 2.0, &mut state, &cfg)
                .unwrap();
            assert_eq!(stats.pruned, 0);
        }
    }

    #[test]
    fn kernel_or_model_switch_forces_refresh() {
        let (x, v, w) = rand_case(60, 3, 3, 45);
        let mut state = BlockBounds::default();
        let run = |st: &mut BlockBounds, kernel, model| {
            NativeBackend.pruned_partials(kernel, &x, &v, &w, 2.0, st, &cfg(model)).unwrap().1.pruned
        };
        run(&mut state, Kernel::FcmFast, BoundModel::Elkan);
        assert_eq!(run(&mut state, Kernel::FcmFast, BoundModel::Elkan), 60);
        // Model switch: no stale cross-model bound may be reused.
        assert_eq!(run(&mut state, Kernel::FcmFast, BoundModel::DMin), 0);
        // Kernel switch: cached u^m rows belong to the other formula.
        assert_eq!(run(&mut state, Kernel::FcmClassic, BoundModel::DMin), 0);
    }

    #[test]
    fn small_shift_prunes_and_elkan_dominates_dmin() {
        // Well-separated blobs → comfortable bounds; a tiny center nudge
        // must prune most records, the per-center model at least as many
        // as the single-d_min model (its test is implied per center), and
        // the pruned partials stay within the perturbation bound.
        let data = crate::data::synth::blobs(400, 3, 3, 0.2, 45);
        let x = &data.features;
        let w = vec![1.0f32; 400];
        let mut v = Matrix::zeros(3, 3);
        for i in 0..3 {
            v.row_mut(i).copy_from_slice(x.row(i * 133));
        }
        let mut v2 = v.clone();
        for val in v2.as_mut_slice().iter_mut() {
            *val += 1e-5;
        }
        let tol = 1e-2;
        let mut counts = Vec::new();
        for model in [BoundModel::DMin, BoundModel::Elkan, BoundModel::Hamerly] {
            let cfg =
                BoundConfig { model, tolerance: tol, refresh_every: 8, quant: QuantMode::Off };
            let mut state = BlockBounds::default();
            NativeBackend
                .pruned_partials(Kernel::FcmFast, x, &v, &w, 2.0, &mut state, &cfg)
                .unwrap();
            let (pruned_p, stats) = NativeBackend
                .pruned_partials(Kernel::FcmFast, x, &v2, &w, 2.0, &mut state, &cfg)
                .unwrap();
            let pruned_n = stats.pruned;
            assert!(pruned_n > 300, "{model:?}: tiny shift should prune most, got {pruned_n}");
            counts.push(pruned_n);
            let exact = fcm_partials_native(x, &v2, &w, 2.0);
            for (a, b) in pruned_p.w_acc.iter().zip(&exact.w_acc) {
                let rel = (a - b).abs() / b.abs().max(1e-9);
                assert!(rel < 10.0 * tol, "{model:?}: pruned w_acc drift {rel} vs {b}");
            }
            let rel = (pruned_p.objective - exact.objective).abs() / exact.objective.max(1e-9);
            assert!(rel < 10.0 * tol, "{model:?}: pruned objective drift {rel}");
        }
        assert!(counts[1] >= counts[0], "elkan ({}) must dominate dmin ({})", counts[1], counts[0]);
        // The hamerly fast test falls back to the elkan per-center test, so
        // its pruned set contains elkan's.
        assert!(
            counts[2] >= counts[1],
            "hamerly ({}) must dominate elkan ({})",
            counts[2],
            counts[1]
        );
    }

    #[test]
    fn classic_pruned_matches_classic_exact_on_refresh() {
        let (x, v, w) = rand_case(90, 4, 4, 46);
        for m in [1.3, 2.0] {
            let mut state = BlockBounds::default();
            let (p, stats) = NativeBackend
                .pruned_partials(Kernel::FcmClassic, &x, &v, &w, m, &mut state, &cfg(BoundModel::Elkan))
                .unwrap();
            assert_eq!(stats.pruned, 0);
            // The pair-loop kernel is the classic oracle.
            let exact = classic_partials_native(&x, &v, &w, m);
            for (a, b) in p.w_acc.iter().zip(&exact.w_acc) {
                assert!((a - b).abs() <= 1e-6 + 1e-4 * b.abs(), "m={m}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn kmeans_pruned_center_update_is_exact_under_small_shift() {
        // Separated clusters: small center movement cannot flip any
        // assignment, so pruned w_acc / v_num must equal the exact pass
        // bit-for-bit (only the objective may lag) — under both models.
        let (c, d, n) = (3usize, 4usize, 300usize);
        let mut rng = Pcg::new(47);
        let mut v = Matrix::zeros(c, d);
        for i in 0..c {
            v.set(i, i % d, 10.0 * (i as f32 + 1.0));
        }
        let mut x = Matrix::zeros(n, d);
        for k in 0..n {
            let home = k % c;
            for j in 0..d {
                x.set(k, j, v.get(home, j) + (rng.normal() * 0.2) as f32);
            }
        }
        let w = vec![1.0f32; n];
        let mut v2 = v.clone();
        for val in v2.as_mut_slice().iter_mut() {
            *val += 0.01;
        }
        for model in [BoundModel::DMin, BoundModel::Elkan, BoundModel::Hamerly] {
            let mut state = BlockBounds::default();
            NativeBackend
                .pruned_partials(Kernel::KMeans, &x, &v, &w, 0.0, &mut state, &cfg(model))
                .unwrap();
            let (pruned_p, stats) = NativeBackend
                .pruned_partials(Kernel::KMeans, &x, &v2, &w, 0.0, &mut state, &cfg(model))
                .unwrap();
            assert!(stats.pruned > 0, "{model:?}: margin test should prune on separated data");
            let exact = kmeans_partials_native(&x, &v2, &w);
            assert_eq!(pruned_p.w_acc, exact.w_acc, "{model:?}: pruned masses must be exact");
            for (a, b) in pruned_p.v_num.as_slice().iter().zip(exact.v_num.as_slice()) {
                assert!((a - b).abs() <= 1e-4 + 1e-5 * b.abs(), "{model:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn bytes_charge_per_center_bound_arrays() {
        // The satellite bugfix: the elkan layout stores an extra n×C lower-
        // bound matrix the slab accounting must charge — C·4 B/record on
        // top of the dmin layout, not the flat 8 B/record it assumed.
        let (n, c) = (50usize, 4usize);
        let (x, v, w) = rand_case(n, 3, c, 48);
        let mut dmin = BlockBounds::default();
        NativeBackend
            .pruned_partials(Kernel::FcmFast, &x, &v, &w, 2.0, &mut dmin, &cfg(BoundModel::DMin))
            .unwrap();
        let mut elkan = BlockBounds::default();
        NativeBackend
            .pruned_partials(Kernel::FcmFast, &x, &v, &w, 2.0, &mut elkan, &cfg(BoundModel::Elkan))
            .unwrap();
        // dmin stores d_min (n), elkan stores lb (n×C) + lb_block (C).
        let extra = (n * c * 4 + c * 4) as u64;
        let dropped = (n * 4) as u64;
        assert_eq!(elkan.bytes(), dmin.bytes() + extra - dropped);
        assert!(dmin.bytes() > (n * (4 + 4) + n * c * 4) as u64);
        let mut st = elkan;
        st.reset();
        assert_eq!(st.bytes(), 0);
        assert!(!st.is_fresh());
    }

    #[test]
    fn hamerly_kmeans_fast_test_beats_dmin_when_far_center_drifts() {
        // Separated clusters; only the *last* center drifts. The dmin
        // margin test pays 2·δ_max everywhere; hamerly charges records of
        // other clusters δ_b (≈0) + max_other, so it must prune at least
        // as many — and the partials stay assignment-exact.
        let (c, d, n) = (3usize, 3usize, 240usize);
        let mut rng = Pcg::new(57);
        let mut v = Matrix::zeros(c, d);
        for i in 0..c {
            v.set(i, i % d, 6.0 * (i as f32 + 1.0));
        }
        let mut x = Matrix::zeros(n, d);
        for k in 0..n {
            let home = k % c;
            for j in 0..d {
                x.set(k, j, v.get(home, j) + (rng.normal() * 0.2) as f32);
            }
        }
        let w = vec![1.0f32; n];
        let mut v2 = v.clone();
        for val in v2.row_mut(c - 1).iter_mut() {
            *val += 0.4; // one drifting center
        }
        let mut counts = Vec::new();
        for model in [BoundModel::DMin, BoundModel::Hamerly] {
            let mut state = BlockBounds::default();
            NativeBackend
                .pruned_partials(Kernel::KMeans, &x, &v, &w, 0.0, &mut state, &cfg(model))
                .unwrap();
            let (p, stats) = NativeBackend
                .pruned_partials(Kernel::KMeans, &x, &v2, &w, 0.0, &mut state, &cfg(model))
                .unwrap();
            counts.push(stats.pruned);
            let exact = kmeans_partials_native(&x, &v2, &w);
            assert_eq!(p.w_acc, exact.w_acc, "{model:?}: pruned masses must stay exact");
        }
        assert!(
            counts[1] >= counts[0],
            "hamerly ({}) must prune at least as much as dmin ({})",
            counts[1],
            counts[0]
        );
        assert!(counts[1] > 0, "hamerly never pruned on separated data");
    }

    #[test]
    fn hamerly_bytes_charge_the_extra_single_bound() {
        // Hamerly stores the elkan layout plus the per-record d_min fast
        // bound — n·4 extra bytes the slab accounting must see.
        let (n, c) = (50usize, 4usize);
        let (x, v, w) = rand_case(n, 3, c, 58);
        let mut elkan = BlockBounds::default();
        NativeBackend
            .pruned_partials(Kernel::FcmFast, &x, &v, &w, 2.0, &mut elkan, &cfg(BoundModel::Elkan))
            .unwrap();
        let mut hamerly = BlockBounds::default();
        NativeBackend
            .pruned_partials(
                Kernel::FcmFast,
                &x,
                &v,
                &w,
                2.0,
                &mut hamerly,
                &cfg(BoundModel::Hamerly),
            )
            .unwrap();
        assert_eq!(hamerly.bytes(), elkan.bytes() + (n * 4) as u64);
    }

    #[test]
    fn score_chunk_rows_are_distributions_and_kmeans_one_hot() {
        let (x, v, _) = rand_case(96, 4, 5, 59);
        for (kernel, m) in [(Kernel::FcmFast, 2.0), (Kernel::FcmClassic, 1.6)] {
            let mut u = Matrix::zeros(96, 5);
            NativeBackend.score_chunk(kernel, &x, &v, m, &mut u).unwrap();
            for k in 0..96 {
                let s: f32 = u.row(k).iter().sum();
                assert!((s - 1.0).abs() < 1e-6, "{kernel:?} row {k} sums to {s}");
                assert!(u.row(k).iter().all(|&ui| (0.0..=1.0 + 1e-6).contains(&ui)));
            }
        }
        let mut u = Matrix::zeros(96, 5);
        NativeBackend.score_chunk(Kernel::KMeans, &x, &v, 0.0, &mut u).unwrap();
        for k in 0..96 {
            let ones = u.row(k).iter().filter(|&&ui| ui == 1.0).count();
            let zeros = u.row(k).iter().filter(|&&ui| ui == 0.0).count();
            assert_eq!((ones, zeros), (1, 4), "K-Means row {k} is not one-hot");
        }
    }

    #[test]
    fn spill_roundtrip_is_bitwise_and_resumes_identically() {
        let (x, v, w) = rand_case(80, 4, 3, 49);
        for (kernel, model, quant) in [
            (Kernel::FcmFast, BoundModel::Elkan, QuantMode::Off),
            (Kernel::FcmFast, BoundModel::DMin, QuantMode::Off),
            (Kernel::FcmFast, BoundModel::Hamerly, QuantMode::Off),
            (Kernel::KMeans, BoundModel::Elkan, QuantMode::Off),
            (Kernel::KMeans, BoundModel::Hamerly, QuantMode::Off),
            (Kernel::FcmFast, BoundModel::Elkan, QuantMode::I8),
            (Kernel::FcmFast, BoundModel::DMin, QuantMode::I8),
            (Kernel::KMeans, BoundModel::Hamerly, QuantMode::I8),
        ] {
            let cfg = BoundConfig { model, tolerance: 1e-2, refresh_every: 8, quant };
            let mut state = BlockBounds::default();
            NativeBackend.pruned_partials(kernel, &x, &v, &w, 2.0, &mut state, &cfg).unwrap();
            let mut v2 = v.clone();
            for val in v2.as_mut_slice().iter_mut() {
                *val += 2e-4;
            }
            NativeBackend.pruned_partials(kernel, &x, &v2, &w, 2.0, &mut state, &cfg).unwrap();
            let img = state.spill().expect("bounds are spillable");
            let mut restored = BlockBounds::unspill(&img).expect("image decodes");
            assert_eq!(img, restored.spill().unwrap(), "{kernel:?}/{model:?}: re-spill differs");
            assert_eq!(state.slab_bytes(), restored.slab_bytes());
            assert_eq!(state.recompute_bytes(), restored.recompute_bytes());
            assert_eq!(state.quant_sidecar_bytes(), restored.quant_sidecar_bytes());
            // The restored state must drive the next pass identically.
            let mut v3 = v2.clone();
            for val in v3.as_mut_slice().iter_mut() {
                *val += 2e-4;
            }
            let (pa, na) = NativeBackend
                .pruned_partials(kernel, &x, &v3, &w, 2.0, &mut state, &cfg)
                .unwrap();
            let (pb, nb) = NativeBackend
                .pruned_partials(kernel, &x, &v3, &w, 2.0, &mut restored, &cfg)
                .unwrap();
            assert_eq!(na, nb, "{kernel:?}/{model:?}: pruning decisions diverged");
            assert_eq!(pa.w_acc, pb.w_acc);
            assert_eq!(pa.v_num.as_slice(), pb.v_num.as_slice());
            assert_eq!(pa.objective, pb.objective);
        }
    }

    /// Well-separated clusters on axis spikes: record `k` sits σ-noise
    /// away from center `k % c`. The geometry every quant test wants —
    /// inter-center distances dwarf both the noise and the i8 step.
    fn grid_case(n: usize, d: usize, c: usize, sigma: f32, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Pcg::new(seed);
        let mut v = Matrix::zeros(c, d);
        for i in 0..c {
            v.set(i, i % d, 6.0 * (i as f32 + 1.0));
        }
        let mut x = Matrix::zeros(n, d);
        for k in 0..n {
            let home = k % c;
            for j in 0..d {
                x.set(k, j, v.get(home, j) + rng.normal() as f32 * sigma);
            }
        }
        (x, v)
    }

    #[test]
    fn quant_second_chance_rescues_fcm_when_path_bound_overcharges() {
        // δ_j is *path length* since refresh: a center that wandered and
        // came back keeps a huge δ although no distance changed. The δ
        // test abandons every record; the sidecar's certified interval —
        // memoryless in δ — re-certifies them, and because the centers
        // really are at their refresh positions the replayed partials
        // match the exact pass.
        let (x, vt) = grid_case(240, 3, 3, 0.2, 61);
        let n = x.rows();
        // Centers offset from the data spikes so every record keeps a
        // distance comfortably above the i8 certification floor.
        let mut v = vt.clone();
        for val in v.as_mut_slice().iter_mut() {
            *val += 1.0;
        }
        let w = vec![1.0f32; n];
        for (model, m) in [
            (BoundModel::Elkan, 2.0),
            (BoundModel::DMin, 2.0),
            (BoundModel::Hamerly, 1.6),
        ] {
            let cfg = cfg_q(model, 0.3);
            let mut state = BlockBounds::default();
            let (_, s0) = NativeBackend
                .pruned_partials(Kernel::FcmFast, &x, &v, &w, m, &mut state, &cfg)
                .unwrap();
            assert_eq!(s0.pruned, 0);
            assert!(s0.sidecar_bytes > 0 && s0.sidecar_build_s >= 0.0);
            // Simulate a wander-and-return trajectory: path length blows
            // up, net displacement is zero.
            state.delta = vec![100.0; 3];
            let (p, stats) = NativeBackend
                .pruned_partials(Kernel::FcmFast, &x, &v, &w, m, &mut state, &cfg)
                .unwrap();
            assert_eq!(
                (stats.pruned, stats.quant),
                (n, n),
                "{model:?}: quant must rescue every abandoned record"
            );
            let exact = fcm_partials_native(&x, &v, &w, m);
            for (a, b) in p.w_acc.iter().zip(&exact.w_acc) {
                assert!((a - b).abs() / b.abs().max(1e-9) < 1e-6, "{model:?}: {a} vs {b}");
            }
            for (a, b) in p.v_num.as_slice().iter().zip(exact.v_num.as_slice()) {
                assert!((a - b).abs() <= 1e-6 + 1e-4 * b.abs(), "{model:?}: {a} vs {b}");
            }
            let rel = (p.objective - exact.objective).abs() / exact.objective.max(1e-9);
            assert!(rel < 1e-4, "{model:?}: objective rel {rel}");
            // Same trajectory with quant off: the δ bound gathers all.
            let off = BoundConfig { model, tolerance: 0.3, refresh_every: 8, quant: QuantMode::Off };
            let mut plain = BlockBounds::default();
            NativeBackend
                .pruned_partials(Kernel::FcmFast, &x, &v, &w, m, &mut plain, &off)
                .unwrap();
            plain.delta = vec![100.0; 3];
            let (_, soff) = NativeBackend
                .pruned_partials(Kernel::FcmFast, &x, &v, &w, m, &mut plain, &off)
                .unwrap();
            assert_eq!((soff.pruned, soff.quant), (0, 0), "{model:?}");
        }
    }

    #[test]
    fn quant_rival_elimination_is_assignment_exact_for_kmeans() {
        let (x, v) = grid_case(240, 3, 3, 0.2, 62);
        let n = x.rows();
        let w = vec![1.0f32; n];
        for model in [BoundModel::DMin, BoundModel::Elkan, BoundModel::Hamerly] {
            let cfg = cfg_q(model, 1e-2);
            let mut state = BlockBounds::default();
            NativeBackend
                .pruned_partials(Kernel::KMeans, &x, &v, &w, 0.0, &mut state, &cfg)
                .unwrap();
            // Path length far beyond every margin: the shift tests die,
            // the certified rival elimination doesn't (the clusters are
            // still separated *now*).
            state.delta = vec![100.0; 3];
            let (p, stats) = NativeBackend
                .pruned_partials(Kernel::KMeans, &x, &v, &w, 0.0, &mut state, &cfg)
                .unwrap();
            assert_eq!((stats.pruned, stats.quant), (n, n), "{model:?}");
            let exact = kmeans_partials_native(&x, &v, &w);
            assert_eq!(p.w_acc, exact.w_acc, "{model:?}: replayed masses must be exact");
            for (a, b) in p.v_num.as_slice().iter().zip(exact.v_num.as_slice()) {
                assert!((a - b).abs() <= 1e-4 + 1e-5 * b.abs(), "{model:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn quant_mode_switch_forces_refresh_and_drops_sidecar() {
        let (x, v, w) = rand_case(90, 4, 3, 63);
        let mut state = BlockBounds::default();
        NativeBackend
            .pruned_partials(Kernel::FcmFast, &x, &v, &w, 2.0, &mut state, &cfg(BoundModel::Elkan))
            .unwrap();
        let bytes_off = state.bytes();
        assert_eq!(state.quant_sidecar_bytes(), 0);
        // off → i8: the cached bounds may not be reused across the layout
        // switch; the refresh pass builds and charges the sidecar.
        let (_, s1) = NativeBackend
            .pruned_partials(
                Kernel::FcmFast,
                &x,
                &v,
                &w,
                2.0,
                &mut state,
                &cfg_q(BoundModel::Elkan, 1e-2),
            )
            .unwrap();
        assert_eq!(s1.pruned, 0, "mode switch must refresh");
        assert!(s1.sidecar_bytes > 0 && s1.sidecar_build_s > 0.0);
        assert_eq!(state.bytes(), bytes_off + s1.sidecar_bytes);
        // Steady i8 pass: the sidecar is not rebuilt.
        let (_, s2) = NativeBackend
            .pruned_partials(
                Kernel::FcmFast,
                &x,
                &v,
                &w,
                2.0,
                &mut state,
                &cfg_q(BoundModel::Elkan, 1e-2),
            )
            .unwrap();
        assert_eq!(s2.pruned, 90);
        assert_eq!(s2.sidecar_bytes, s1.sidecar_bytes);
        assert_eq!(s2.sidecar_build_s, 0.0, "sidecar must be built exactly once");
        // i8 → off: refresh again, sidecar dropped and de-charged.
        let (_, s3) = NativeBackend
            .pruned_partials(Kernel::FcmFast, &x, &v, &w, 2.0, &mut state, &cfg(BoundModel::Elkan))
            .unwrap();
        assert_eq!((s3.pruned, s3.sidecar_bytes), (0, 0));
        assert_eq!(state.bytes(), bytes_off);
        assert_eq!(state.quant_sidecar_bytes(), 0);
    }

    #[test]
    fn quant_bytes_charge_sidecar_and_widened_dmin_layout() {
        let (n, c, d) = (50usize, 4usize, 3usize);
        let (x, v, w) = rand_case(n, d, c, 64);
        let run = |quant: QuantMode, model: BoundModel| {
            let mut st = BlockBounds::default();
            let cfg = BoundConfig { model, tolerance: 1e-2, refresh_every: 8, quant };
            NativeBackend.pruned_partials(Kernel::FcmFast, &x, &v, &w, 2.0, &mut st, &cfg).unwrap();
            st
        };
        let elkan_off = run(QuantMode::Off, BoundModel::Elkan);
        let elkan_i8 = run(QuantMode::I8, BoundModel::Elkan);
        let sidecar = elkan_i8.quant_sidecar_bytes();
        assert_eq!(sidecar, (n * d + 4 * d + 16) as u64);
        assert_eq!(elkan_i8.bytes(), elkan_off.bytes() + sidecar);
        // dmin gains the lb matrix + block minima under quant (the second
        // chance certifies against them) — charged, on top of the sidecar.
        let dmin_off = run(QuantMode::Off, BoundModel::DMin);
        let dmin_i8 = run(QuantMode::I8, BoundModel::DMin);
        assert_eq!(dmin_i8.bytes(), dmin_off.bytes() + sidecar + ((n * c + c) * 4) as u64);
    }

    #[test]
    fn unspill_rejects_garbage() {
        assert!(BlockBounds::unspill(&[]).is_none());
        assert!(BlockBounds::unspill(&[0u8; 16]).is_none());
        let mut state = BlockBounds::default();
        let (x, v, w) = rand_case(10, 2, 2, 50);
        NativeBackend
            .pruned_partials(Kernel::FcmFast, &x, &v, &w, 2.0, &mut state, &cfg(BoundModel::Elkan))
            .unwrap();
        let img = state.spill().unwrap();
        let mut truncated = img.clone();
        truncated.truncate(img.len() - 3);
        assert!(BlockBounds::unspill(&truncated).is_none(), "truncated image must not decode");
        // A single flipped payload bit must fail the checksum, not decode
        // into silently wrong bounds.
        let mut flipped = img.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x40;
        assert!(BlockBounds::unspill(&flipped).is_none(), "corrupt image must not decode");
    }
}
