//! Center seeding strategies.
//!
//! The paper contrasts *random* initial centers (what Mahout does per job)
//! with its driver-side sampled pre-clustering. Both live here, plus a
//! k-means++-style spread seeding used as an optional extension (the paper's
//! "future work: tuning the required parameters").

use crate::data::matrix::dist2;
use crate::data::Matrix;
use crate::prng::Pcg;

/// Pick `c` distinct records as initial centers (the baseline strategy).
pub fn random_records(x: &Matrix, c: usize, rng: &mut Pcg) -> Matrix {
    assert!(x.rows() >= c, "need at least c records to seed");
    let idx = rng.sample_indices(x.rows(), c);
    x.select_rows(&idx)
}

/// Uniform random points inside the per-feature bounding box.
pub fn random_uniform(x: &Matrix, c: usize, rng: &mut Pcg) -> Matrix {
    let d = x.cols();
    let mut lo = vec![f32::INFINITY; d];
    let mut hi = vec![f32::NEG_INFINITY; d];
    for row in x.iter_rows() {
        for j in 0..d {
            lo[j] = lo[j].min(row[j]);
            hi[j] = hi[j].max(row[j]);
        }
    }
    let mut out = Matrix::zeros(c, d);
    for i in 0..c {
        for j in 0..d {
            out.set(i, j, rng.uniform(lo[j] as f64, hi[j] as f64) as f32);
        }
    }
    out
}

/// k-means++ seeding: spread centers by D² sampling (extension knob).
pub fn kmeanspp(x: &Matrix, c: usize, rng: &mut Pcg) -> Matrix {
    assert!(x.rows() >= c);
    let n = x.rows();
    let mut chosen = Vec::with_capacity(c);
    chosen.push(rng.next_index(n));
    let mut d2 = vec![f64::INFINITY; n];
    while chosen.len() < c {
        let last = *chosen.last().unwrap();
        for i in 0..n {
            d2[i] = d2[i].min(dist2(x.row(i), x.row(last)));
        }
        let pick = rng.weighted_index(&d2);
        chosen.push(pick);
    }
    x.select_rows(&chosen)
}

/// Detect near-duplicate centers and relocate them to the records farthest
/// from every current center (classic duplicate/empty-cluster repair).
///
/// Near-zero-variance clusters (e.g. KDD99's smurf flood, where records are
/// practically identical) can capture several centers during FCM descent;
/// the duplicates waste capacity while barely moving the objective, so
/// objective-based restart selection cannot repair them. Returns the number
/// of centers relocated (0 = nothing to repair).
pub fn repair_duplicate_centers(x: &Matrix, centers: &mut Matrix, rel_tol: f64) -> usize {
    let c = centers.rows();
    if c < 2 {
        return 0;
    }
    // Scale: mean pairwise center distance.
    let mut mean_d2 = 0.0f64;
    let mut pairs = 0usize;
    for i in 0..c {
        for j in (i + 1)..c {
            mean_d2 += dist2(centers.row(i), centers.row(j));
            pairs += 1;
        }
    }
    mean_d2 /= pairs.max(1) as f64;
    // All-coincident centers give mean_d2 = 0; fall back to the data scale
    // (mean squared record distance to the first center) so full collapse
    // is still detected and repaired.
    if mean_d2 <= f64::MIN_POSITIVE {
        let n = x.rows().max(1);
        mean_d2 = (0..n)
            .step_by((n / 256).max(1))
            .map(|r| x.row_dist2(r, centers.row(0)))
            .sum::<f64>()
            / (n.div_ceil((n / 256).max(1)) as f64);
    }
    let threshold = mean_d2 * rel_tol * rel_tol;

    // Mark duplicates: for each close pair, the higher index is relocated.
    let mut dup = vec![false; c];
    for i in 0..c {
        if dup[i] {
            continue;
        }
        for j in (i + 1)..c {
            if !dup[j] && dist2(centers.row(i), centers.row(j)) < threshold {
                dup[j] = true;
            }
        }
    }
    let n_dup = dup.iter().filter(|&&d| d).count();
    if n_dup == 0 {
        return 0;
    }
    // Farthest-point reseeding (deterministic): iteratively move each
    // duplicate to the record with max distance to all kept centers.
    let mut d2min: Vec<f64> = (0..x.rows())
        .map(|r| {
            (0..c)
                .filter(|&i| !dup[i])
                .map(|i| x.row_dist2(r, centers.row(i)))
                .fold(f64::INFINITY, f64::min)
        })
        .collect();
    for i in 0..c {
        if !dup[i] {
            continue;
        }
        let far = d2min
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(r, _)| r)
            .unwrap_or(0);
        let row = x.row(far).to_vec();
        centers.row_mut(i).copy_from_slice(&row);
        for (r, d) in d2min.iter_mut().enumerate() {
            *d = d.min(x.row_dist2(r, &row));
        }
    }
    n_dup
}

/// Named strategy selector for config/CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Seeding {
    RandomRecords,
    RandomUniform,
    KMeansPlusPlus,
}

impl Seeding {
    pub fn seed(&self, x: &Matrix, c: usize, rng: &mut Pcg) -> Matrix {
        match self {
            Seeding::RandomRecords => random_records(x, c, rng),
            Seeding::RandomUniform => random_uniform(x, c, rng),
            Seeding::KMeansPlusPlus => kmeanspp(x, c, rng),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;

    #[test]
    fn random_records_are_records() {
        let data = blobs(50, 3, 2, 0.3, 1);
        let mut rng = Pcg::new(1);
        let seeds = random_records(&data.features, 4, &mut rng);
        assert_eq!(seeds.rows(), 4);
        for i in 0..4 {
            let is_record = (0..50).any(|j| data.features.row(j) == seeds.row(i));
            assert!(is_record);
        }
    }

    #[test]
    fn random_uniform_inside_bbox() {
        let data = blobs(100, 2, 2, 0.3, 2);
        let mut rng = Pcg::new(2);
        let seeds = random_uniform(&data.features, 8, &mut rng);
        let m = &data.features;
        for j in 0..2 {
            let lo = (0..100).map(|i| m.get(i, j)).fold(f32::INFINITY, f32::min);
            let hi = (0..100).map(|i| m.get(i, j)).fold(f32::NEG_INFINITY, f32::max);
            for i in 0..8 {
                assert!(seeds.get(i, j) >= lo && seeds.get(i, j) <= hi);
            }
        }
    }

    #[test]
    fn kmeanspp_spreads_across_blobs() {
        // 3 well-separated blobs, 3 seeds → expect one seed near each blob.
        let data = blobs(300, 2, 3, 0.1, 3);
        let mut hits = 0;
        for trial in 0..5 {
            let mut rng = Pcg::new(100 + trial);
            let seeds = kmeanspp(&data.features, 3, &mut rng);
            let labels = data.labels.as_ref().unwrap();
            let mut covered = std::collections::HashSet::new();
            for i in 0..3 {
                let mut best = (f64::INFINITY, 0usize);
                for j in 0..300 {
                    let d = data.features.row_dist2(j, seeds.row(i));
                    if d < best.0 {
                        best = (d, labels[j]);
                    }
                }
                covered.insert(best.1);
            }
            if covered.len() == 3 {
                hits += 1;
            }
        }
        assert!(hits >= 4, "kmeans++ covered all blobs only {hits}/5 times");
    }

    #[test]
    fn seeding_enum_dispatch() {
        let data = blobs(30, 2, 2, 0.2, 4);
        let mut rng = Pcg::new(5);
        for s in [Seeding::RandomRecords, Seeding::RandomUniform, Seeding::KMeansPlusPlus] {
            let m = s.seed(&data.features, 2, &mut rng);
            assert_eq!((m.rows(), m.cols()), (2, 2));
        }
    }
}
