//! Checksummed session checkpoints — the recovery half of the chaos layer.
//!
//! Every `session.checkpoint_every` iterations the convergence loop writes
//! the full resumable state (centers, per-center weight mass, iteration
//! count, objective) to a single checkpoint file. The image reuses the
//! crate's codec discipline: length-prefixed fields through the
//! [`crate::fcm::backend`] helpers, an FNV-1a trailer over the whole
//! payload, and a magic/version header — so a torn write, a bit flip or a
//! file that is not a checkpoint at all is rejected loudly at load time
//! instead of silently warm-starting a session from garbage.
//!
//! Resume semantics (`bigfcm session --resume <path>`): the loaded centers
//! become the seed `v0` and the iteration budget continues from
//! `iteration`, so a run killed at iteration k and resumed converges to the
//! same centers as the uninterrupted run (bitwise with pruning off — the
//! per-iteration math is a pure function of the incoming centers).

use std::path::Path;

use crate::data::Matrix;
use crate::error::{Error, Result};
use crate::fcm::backend::{put_f64, put_f64s, put_matrix, put_u32, put_u64, put_u8, Cur};
use crate::fcm::{SessionAlgo, Variant};
use crate::hdfs::fnv1a;

/// Checkpoint file magic (little-endian first field of every image).
pub const CHECKPOINT_MAGIC: u32 = 0xB16F_C4EC;
/// Bumped on any layout change; loaders reject unknown versions.
pub const CHECKPOINT_VERSION: u8 = 1;

/// The resumable state of an iteration-resident convergence loop.
#[derive(Clone, Debug)]
pub struct SessionCheckpoint {
    /// Which per-iteration partials the session computes.
    pub algo: SessionAlgo,
    /// FCM chunk-math variant (ignored for K-Means, stored anyway so a
    /// resume cannot silently switch math).
    pub variant: Variant,
    /// Iterations completed when this checkpoint was taken.
    pub iteration: u64,
    /// Objective after `iteration` iterations.
    pub objective: f64,
    /// Fuzzifier the run used — resume refuses nothing, but the CLI prints
    /// it so a mismatched `--m` is visible.
    pub m: f64,
    /// Centers after `iteration` iterations (the resume seed).
    pub centers: Matrix,
    /// Per-center weight mass after `iteration` iterations.
    pub weights: Vec<f64>,
}

fn algo_tag(a: SessionAlgo) -> u8 {
    match a {
        SessionAlgo::Fcm => 0,
        SessionAlgo::KMeans => 1,
    }
}

fn variant_tag(v: Variant) -> u8 {
    match v {
        Variant::Fast => 0,
        Variant::Classic => 1,
    }
}

impl SessionCheckpoint {
    /// Serialise to the checksummed image (header, fields, FNV-1a trailer).
    pub fn encode(&self) -> Vec<u8> {
        let mut b =
            Vec::with_capacity(self.centers.rows() * self.centers.cols() * 4 + 128);
        put_u32(&mut b, CHECKPOINT_MAGIC);
        put_u8(&mut b, CHECKPOINT_VERSION);
        put_u8(&mut b, algo_tag(self.algo));
        put_u8(&mut b, variant_tag(self.variant));
        put_u64(&mut b, self.iteration);
        put_f64(&mut b, self.objective);
        put_f64(&mut b, self.m);
        put_matrix(&mut b, &self.centers);
        put_f64s(&mut b, &self.weights);
        let sum = fnv1a(&b);
        put_u64(&mut b, sum);
        b
    }

    /// Decode an image, rejecting corruption, truncation, foreign files and
    /// unknown versions with a structured [`Error::Checkpoint`].
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        fn fail(m: &str) -> Error {
            Error::Checkpoint(m.to_string())
        }
        if bytes.len() < 8 {
            return Err(fail("truncated (shorter than its checksum trailer)"));
        }
        let (payload, trailer) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(trailer.try_into().expect("8-byte trailer"));
        let computed = fnv1a(payload);
        if stored != computed {
            return Err(Error::Checkpoint(format!(
                "checksum mismatch (stored {stored:#018x}, computed {computed:#018x}) — \
                 refusing to resume from a corrupt checkpoint"
            )));
        }
        let mut c = Cur::new(payload);
        match c.u32() {
            Some(CHECKPOINT_MAGIC) => {}
            Some(other) => {
                return Err(Error::Checkpoint(format!(
                    "bad magic {other:#010x} — not a session checkpoint"
                )))
            }
            None => return Err(fail("truncated header")),
        }
        match c.u8() {
            Some(CHECKPOINT_VERSION) => {}
            Some(v) => {
                return Err(Error::Checkpoint(format!("unknown checkpoint version {v}")))
            }
            None => return Err(fail("truncated header")),
        }
        let algo = match c.u8() {
            Some(0) => SessionAlgo::Fcm,
            Some(1) => SessionAlgo::KMeans,
            _ => return Err(fail("bad algo tag")),
        };
        let variant = match c.u8() {
            Some(0) => Variant::Fast,
            Some(1) => Variant::Classic,
            _ => return Err(fail("bad variant tag")),
        };
        let iteration = c.u64().ok_or_else(|| fail("truncated iteration"))?;
        let objective = c.f64().ok_or_else(|| fail("truncated objective"))?;
        let m = c.f64().ok_or_else(|| fail("truncated fuzzifier"))?;
        let centers = c.matrix().ok_or_else(|| fail("truncated centers"))?;
        let weights = c.f64s().ok_or_else(|| fail("truncated weights"))?;
        if weights.len() != centers.rows() {
            return Err(Error::Checkpoint(format!(
                "weights length {} != centers rows {}",
                weights.len(),
                centers.rows()
            )));
        }
        if !c.done() {
            return Err(fail("trailing bytes after checkpoint payload"));
        }
        Ok(Self { algo, variant, iteration, objective, m, centers, weights })
    }

    /// Write the image to `path` (creating parent directories), returning
    /// the bytes written — the per-checkpoint overhead figure.
    pub fn save(&self, path: &Path) -> Result<u64> {
        let img = self.encode();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(|e| Error::io(parent, e))?;
            }
        }
        std::fs::write(path, &img).map_err(|e| Error::io(path, e))?;
        Ok(img.len() as u64)
    }

    /// Read and decode `path`, prefixing decode failures with the path so
    /// "which checkpoint was corrupt" survives into the CLI error.
    pub fn load(path: &Path) -> Result<Self> {
        let img = std::fs::read(path).map_err(|e| Error::io(path, e))?;
        Self::decode(&img).map_err(|e| match e {
            Error::Checkpoint(m) => {
                Error::Checkpoint(format!("{}: {m}", path.display()))
            }
            other => other,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::corrupt_image;

    fn sample() -> SessionCheckpoint {
        SessionCheckpoint {
            algo: SessionAlgo::Fcm,
            variant: Variant::Fast,
            iteration: 7,
            objective: 123.456789,
            m: 2.0,
            centers: Matrix::from_rows(&[vec![1.5, -2.25, 0.125], vec![4.0, 5.5, -6.75]]),
            weights: vec![10.0, 20.5],
        }
    }

    #[test]
    fn roundtrip_is_bitwise() {
        let cp = sample();
        let back = SessionCheckpoint::decode(&cp.encode()).unwrap();
        assert_eq!(back.algo, SessionAlgo::Fcm);
        assert_eq!(back.variant, Variant::Fast);
        assert_eq!(back.iteration, 7);
        assert_eq!(back.objective.to_bits(), cp.objective.to_bits());
        assert_eq!(back.m.to_bits(), cp.m.to_bits());
        assert_eq!(back.centers.as_slice(), cp.centers.as_slice());
        assert_eq!(back.weights, cp.weights);
    }

    #[test]
    fn save_load_roundtrips_on_disk() {
        let dir = std::env::temp_dir().join(format!("bigfcm_ckpt_{}", std::process::id()));
        let path = dir.join("nested").join("s.ckpt");
        let cp = sample();
        let bytes = cp.save(&path).unwrap();
        assert_eq!(bytes, cp.encode().len() as u64);
        let back = SessionCheckpoint::load(&path).unwrap();
        assert_eq!(back.centers.as_slice(), cp.centers.as_slice());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn any_single_byte_flip_is_rejected() {
        let img = sample().encode();
        // corrupt_image picks a seeded byte; sweep several seeds so flips
        // land in the header, the payload and the trailer across runs.
        for seed in 0..16u64 {
            let mut bad = img.clone();
            corrupt_image(&mut bad, seed);
            assert_ne!(bad, img, "seed {seed} corrupted nothing");
            let err = SessionCheckpoint::decode(&bad).unwrap_err();
            assert!(
                matches!(err, Error::Checkpoint(_)),
                "seed {seed}: wrong error {err}"
            );
        }
    }

    #[test]
    fn truncation_and_foreign_magic_are_rejected() {
        let img = sample().encode();
        assert!(SessionCheckpoint::decode(&img[..4]).is_err());
        assert!(SessionCheckpoint::decode(&[]).is_err());
        // A well-checksummed image with the wrong magic is "not a
        // checkpoint", not "corrupt": rebuild the trailer after the edit.
        let mut foreign = img[..img.len() - 8].to_vec();
        foreign[0] ^= 0xFF;
        let sum = fnv1a(&foreign);
        put_u64(&mut foreign, sum);
        let err = SessionCheckpoint::decode(&foreign).unwrap_err();
        assert!(err.to_string().contains("not a session checkpoint"), "{err}");
    }

    #[test]
    fn load_error_carries_path() {
        let dir = std::env::temp_dir().join(format!("bigfcm_ckpt_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corrupt.ckpt");
        let mut img = sample().encode();
        corrupt_image(&mut img, 3);
        std::fs::write(&path, &img).unwrap();
        let err = SessionCheckpoint::load(&path).unwrap_err();
        assert!(err.to_string().contains("corrupt.ckpt"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
