//! Core clustering algorithms: fast (Kolen–Hutcheson) FCM, classic FCM,
//! weighted FCM, the block-wise WFCMPB of the paper's Algorithm 2, K-Means,
//! plus seeding and convergence policy.
//!
//! All loops are generic over a [`KernelBackend`] — the unified contract
//! of [`backend`] owning exact partials, pruned partials and the per-block
//! bound state — so the same code drives the pure-rust native
//! implementation (tests, driver-side small jobs), the AOT HLO executables
//! on PJRT (the production hot path) and the offline PJRT shim.

pub mod backend;
pub mod checkpoint;
pub mod loops;
pub mod native;
pub mod quant;
pub mod seeding;
pub mod wfcmpb;

pub use backend::{
    memberships_from_bounds, BlockBounds, BoundConfig, BoundModel, BoundRows, Kernel,
    KernelBackend, PruneStats, QuantMode,
};
pub use checkpoint::SessionCheckpoint;
pub use quant::{QuantCenters, QuantSidecar};
pub use loops::{
    kmeans_loop, run_fcm, run_fcm_session, run_fcm_session_sharded, CheckpointPolicy, FcmParams,
    PruneConfig, SessionAlgo, SessionRunResult, ShardedSessionRunResult, Variant,
};
pub use native::NativeBackend;

use crate::data::Matrix;

/// Partial sufficient statistics of one pass over some records:
/// un-normalised center numerators, per-cluster weight mass, and the
/// weighted objective (paper Eq. 2).
#[derive(Clone, Debug)]
pub struct Partials {
    /// Σ_k u^m_{ik} w_k x_k, shape (C, d).
    pub v_num: Matrix,
    /// Σ_k u^m_{ik} w_k, length C.
    pub w_acc: Vec<f64>,
    /// Σ_ik u^m_{ik} w_k ‖x_k − v_i‖².
    pub objective: f64,
}

impl Partials {
    pub fn zeros(c: usize, d: usize) -> Self {
        Self { v_num: Matrix::zeros(c, d), w_acc: vec![0.0; c], objective: 0.0 }
    }

    /// Merge another partial into this one (associative, commutative — the
    /// combiner contract).
    pub fn merge(&mut self, other: &Partials) {
        debug_assert_eq!(self.v_num.rows(), other.v_num.rows());
        debug_assert_eq!(self.v_num.cols(), other.v_num.cols());
        for (a, b) in self
            .v_num
            .as_mut_slice()
            .iter_mut()
            .zip(other.v_num.as_slice())
        {
            *a += b;
        }
        for (a, b) in self.w_acc.iter_mut().zip(&other.w_acc) {
            *a += b;
        }
        self.objective += other.objective;
    }

    /// Serialised footprint: centers f32 + weights f64 + objective f64 —
    /// the single source for the shuffle cost model and slab accounting.
    pub fn encoded_bytes(&self) -> u64 {
        (self.v_num.rows() * self.v_num.cols() * 4 + self.w_acc.len() * 8 + 8) as u64
    }

    /// Finish the update: centers = numerators / weights. Clusters with no
    /// mass keep `fallback`'s row (Mahout's empty-cluster behaviour).
    pub fn into_centers(self, fallback: &Matrix) -> Matrix {
        let (c, d) = (self.v_num.rows(), self.v_num.cols());
        let mut out = Matrix::zeros(c, d);
        for i in 0..c {
            let wi = self.w_acc[i];
            let row = out.row_mut(i);
            if wi > 1e-30 {
                for (j, val) in row.iter_mut().enumerate() {
                    *val = (self.v_num.get(i, j) as f64 / wi) as f32;
                }
            } else {
                row.copy_from_slice(fallback.row(i));
            }
        }
        out
    }
}

/// The outcome of a clustering run.
#[derive(Clone, Debug)]
pub struct ClusterResult {
    /// Final centers, (C, d).
    pub centers: Matrix,
    /// Final per-center weight mass (importance for downstream WFCM).
    pub weights: Vec<f64>,
    /// Iterations executed.
    pub iterations: usize,
    /// Final objective value.
    pub objective: f64,
    /// Whether the epsilon criterion was met (vs hitting max_iterations).
    pub converged: bool,
}

/// Max squared center displacement — the paper's convergence statistic
/// (`max_i ‖V_i,new − V_i,old‖²`).
pub fn max_center_shift2(old: &Matrix, new: &Matrix) -> f64 {
    debug_assert_eq!(old.rows(), new.rows());
    let mut worst = 0.0f64;
    for i in 0..old.rows() {
        let mut acc = 0.0f64;
        for (a, b) in old.row(i).iter().zip(new.row(i)) {
            let d = (*a - *b) as f64;
            acc += d * d;
        }
        worst = worst.max(acc);
    }
    worst
}

/// Hard assignment of each record to its nearest center (used for the
/// confusion-matrix evaluation; for FCM this is the argmax-membership rule,
/// which coincides with nearest-center for any m).
pub fn assign_hard(x: &Matrix, centers: &Matrix) -> Vec<usize> {
    let mut out = Vec::with_capacity(x.rows());
    for i in 0..x.rows() {
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for c in 0..centers.rows() {
            let d = x.row_dist2(i, centers.row(c));
            if d < best_d {
                best_d = d;
                best = c;
            }
        }
        out.push(best);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partials_merge_is_componentwise() {
        let mut a = Partials::zeros(2, 2);
        a.v_num.set(0, 0, 1.0);
        a.w_acc[0] = 2.0;
        a.objective = 3.0;
        let mut b = Partials::zeros(2, 2);
        b.v_num.set(0, 0, 4.0);
        b.w_acc[0] = 5.0;
        b.objective = 6.0;
        a.merge(&b);
        assert_eq!(a.v_num.get(0, 0), 5.0);
        assert_eq!(a.w_acc[0], 7.0);
        assert_eq!(a.objective, 9.0);
    }

    #[test]
    fn into_centers_divides_and_falls_back() {
        let mut p = Partials::zeros(2, 1);
        p.v_num.set(0, 0, 6.0);
        p.w_acc[0] = 2.0;
        // cluster 1 gets no mass → falls back.
        let fallback = Matrix::from_rows(&[vec![9.0], vec![7.0]]);
        let centers = p.into_centers(&fallback);
        assert_eq!(centers.get(0, 0), 3.0);
        assert_eq!(centers.get(1, 0), 7.0);
    }

    #[test]
    fn shift_is_max_over_clusters() {
        let a = Matrix::from_rows(&[vec![0.0, 0.0], vec![1.0, 1.0]]);
        let b = Matrix::from_rows(&[vec![0.0, 1.0], vec![1.0, 1.0]]);
        assert_eq!(max_center_shift2(&a, &b), 1.0);
    }

    #[test]
    fn hard_assignment_nearest() {
        let x = Matrix::from_rows(&[vec![0.1], vec![4.9], vec![2.4]]);
        let v = Matrix::from_rows(&[vec![0.0], vec![5.0]]);
        assert_eq!(assign_hard(&x, &v), vec![0, 1, 0]);
    }
}
