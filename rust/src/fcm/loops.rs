//! Outer iteration loops: weighted FCM (fast or classic chunk math) and
//! Lloyd's K-Means, generic over the chunk backend.
//!
//! Layer 3 owns these loops by design — the AOT artifacts only compute one
//! pass of partials, so convergence policy (epsilon on the max squared
//! center shift, iteration cap) lives here in rust, identical for the
//! native and PJRT backends.

use crate::data::Matrix;
use crate::error::{Error, Result};
use crate::fcm::{max_center_shift2, ChunkBackend, ClusterResult, Partials};

/// FCM chunk-math variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Kolen–Hutcheson O(n·c) update (paper Algorithm 1).
    Fast,
    /// Textbook O(n·c²) update ("basic FCM").
    Classic,
}

/// Parameters of one FCM run (paper notation).
#[derive(Clone, Copy, Debug)]
pub struct FcmParams {
    /// Fuzzifier m > 1.
    pub m: f64,
    /// Convergence threshold on max squared center shift.
    pub epsilon: f64,
    /// Iteration cap (paper uses 1000).
    pub max_iterations: usize,
    /// Chunk-math variant.
    pub variant: Variant,
}

impl Default for FcmParams {
    fn default() -> Self {
        Self { m: 2.0, epsilon: 5.0e-7, max_iterations: 1000, variant: Variant::Fast }
    }
}

fn one_pass(
    backend: &dyn ChunkBackend,
    x: &Matrix,
    v: &Matrix,
    w: &[f32],
    params: &FcmParams,
) -> Result<Partials> {
    match params.variant {
        Variant::Fast => backend.fcm_partials(x, v, w, params.m),
        Variant::Classic => backend.classic_partials(x, v, w, params.m),
    }
}

/// Weighted FCM to convergence over in-memory records.
///
/// This is the paper's Algorithm 1 (WFCM): each iteration computes weighted
/// membership terms and center numerators in one pass, then divides. The
/// final per-center weights (Σ u^m w) are returned as the center importance
/// used by downstream WFCM merges (paper Eq. 6).
pub fn run_fcm(
    backend: &dyn ChunkBackend,
    x: &Matrix,
    w: &[f32],
    v0: Matrix,
    params: &FcmParams,
) -> Result<ClusterResult> {
    if x.rows() == 0 {
        return Err(Error::Clustering("empty input".into()));
    }
    if x.rows() != w.len() {
        return Err(Error::Clustering(format!(
            "weights length {} != rows {}",
            w.len(),
            x.rows()
        )));
    }
    if v0.cols() != x.cols() {
        return Err(Error::Clustering("seed center dims mismatch".into()));
    }
    let mut v = v0;
    let mut weights = vec![0.0; v.rows()];
    let mut objective = f64::INFINITY;
    let mut converged = false;
    let mut iterations = 0;
    for it in 1..=params.max_iterations {
        iterations = it;
        let partials = one_pass(backend, x, &v, w, params)?;
        weights.clone_from_slice(&partials.w_acc);
        objective = partials.objective;
        let v_new = partials.into_centers(&v);
        let shift = max_center_shift2(&v, &v_new);
        v = v_new;
        if shift <= params.epsilon {
            converged = true;
            break;
        }
    }
    Ok(ClusterResult { centers: v, weights, iterations, objective, converged })
}

/// Lloyd's K-Means to convergence (the Mahout-KM compute model).
pub fn kmeans_loop(
    backend: &dyn ChunkBackend,
    x: &Matrix,
    v0: Matrix,
    epsilon: f64,
    max_iterations: usize,
) -> Result<ClusterResult> {
    if x.rows() == 0 {
        return Err(Error::Clustering("empty input".into()));
    }
    let w = vec![1.0f32; x.rows()];
    let mut v = v0;
    let mut weights = vec![0.0; v.rows()];
    let mut objective = f64::INFINITY;
    let mut converged = false;
    let mut iterations = 0;
    for it in 1..=max_iterations {
        iterations = it;
        let partials = backend.kmeans_partials(x, &v, &w)?;
        weights.clone_from_slice(&partials.w_acc);
        objective = partials.objective;
        let v_new = partials.into_centers(&v);
        let shift = max_center_shift2(&v, &v_new);
        v = v_new;
        if shift <= epsilon {
            converged = true;
            break;
        }
    }
    Ok(ClusterResult { centers: v, weights, iterations, objective, converged })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;
    use crate::fcm::seeding;
    use crate::fcm::NativeBackend;
    use crate::prng::Pcg;

    #[test]
    fn fcm_recovers_blobs() {
        let data = blobs(600, 3, 3, 0.15, 1);
        let mut rng = Pcg::new(2);
        let v0 = seeding::random_records(&data.features, 3, &mut rng);
        let w = vec![1.0f32; 600];
        let params = FcmParams { epsilon: 1e-10, ..Default::default() };
        let r = run_fcm(&NativeBackend, &data.features, &w, v0, &params).unwrap();
        assert!(r.converged, "did not converge in {} iters", r.iterations);
        // Every found center sits inside some blob (spread 0.15 → within 0.5).
        let truth = crate::fcm::assign_hard(&r.centers, &r.centers);
        assert_eq!(truth.len(), 3);
        for i in 0..3 {
            let mut best = f64::INFINITY;
            for j in 0..600 {
                best = best.min(data.features.row_dist2(j, r.centers.row(i)));
            }
            assert!(best < 0.25, "center {i} far from data: {best}");
        }
    }

    #[test]
    fn objective_monotone_nonincreasing() {
        let data = blobs(400, 4, 3, 0.4, 3);
        let mut rng = Pcg::new(4);
        let v0 = seeding::random_records(&data.features, 3, &mut rng);
        let w = vec![1.0f32; 400];
        let mut v = v0;
        let mut last = f64::INFINITY;
        for _ in 0..10 {
            let p = NativeBackend.fcm_partials(&data.features, &v, &w, 2.0).unwrap();
            assert!(p.objective <= last * (1.0 + 1e-7), "{} > {last}", p.objective);
            last = p.objective;
            v = p.into_centers(&v);
        }
    }

    #[test]
    fn fast_and_classic_converge_to_same_centers() {
        let data = blobs(300, 3, 3, 0.3, 5);
        let mut rng = Pcg::new(6);
        let v0 = seeding::random_records(&data.features, 3, &mut rng);
        let w = vec![1.0f32; 300];
        let fast = run_fcm(
            &NativeBackend,
            &data.features,
            &w,
            v0.clone(),
            &FcmParams { epsilon: 1e-12, variant: Variant::Fast, ..Default::default() },
        )
        .unwrap();
        let classic = run_fcm(
            &NativeBackend,
            &data.features,
            &w,
            v0,
            &FcmParams { epsilon: 1e-12, variant: Variant::Classic, ..Default::default() },
        )
        .unwrap();
        let shift = max_center_shift2(&fast.centers, &classic.centers);
        assert!(shift < 1e-4, "variants diverged: {shift}");
    }

    #[test]
    fn kmeans_recovers_blobs() {
        let data = blobs(600, 3, 3, 0.15, 7);
        let mut rng = Pcg::new(8);
        let v0 = seeding::random_records(&data.features, 3, &mut rng);
        let r = kmeans_loop(&NativeBackend, &data.features, v0, 1e-10, 500).unwrap();
        assert!(r.converged);
        assert!(r.objective / 600.0 < 0.2, "per-record SSE {}", r.objective / 600.0);
    }

    #[test]
    fn weighted_points_pull_centers() {
        // One heavy point at 10 must pull its cluster center toward it.
        let x = Matrix::from_rows(&[vec![0.0], vec![1.0], vec![10.0], vec![11.0]]);
        let w_uniform = vec![1.0f32, 1.0, 1.0, 1.0];
        let w_heavy = vec![1.0f32, 1.0, 50.0, 1.0];
        let v0 = Matrix::from_rows(&[vec![0.5], vec![10.5]]);
        let p = FcmParams { epsilon: 1e-12, ..Default::default() };
        let a = run_fcm(&NativeBackend, &x, &w_uniform, v0.clone(), &p).unwrap();
        let b = run_fcm(&NativeBackend, &x, &w_heavy, v0, &p).unwrap();
        // Heavy cluster center must be closer to 10 than the uniform one.
        let ua = a.centers.get(1, 0);
        let ub = b.centers.get(1, 0);
        assert!((ub - 10.0).abs() < (ua - 10.0).abs(), "{ua} vs {ub}");
    }

    #[test]
    fn rejects_bad_inputs() {
        let x = Matrix::zeros(0, 3);
        let v0 = Matrix::zeros(2, 3);
        assert!(run_fcm(&NativeBackend, &x, &[], v0.clone(), &FcmParams::default()).is_err());
        let x = Matrix::zeros(4, 3);
        assert!(run_fcm(&NativeBackend, &x, &[1.0; 3], v0.clone(), &FcmParams::default()).is_err());
        let v_bad = Matrix::zeros(2, 5);
        assert!(run_fcm(&NativeBackend, &x, &[1.0; 4], v_bad, &FcmParams::default()).is_err());
    }

    #[test]
    fn iteration_cap_respected() {
        let data = blobs(200, 3, 3, 0.4, 9);
        let mut rng = Pcg::new(10);
        let v0 = seeding::random_records(&data.features, 3, &mut rng);
        let w = vec![1.0f32; 200];
        let r = run_fcm(
            &NativeBackend,
            &data.features,
            &w,
            v0,
            &FcmParams { epsilon: 0.0, max_iterations: 5, ..Default::default() },
        )
        .unwrap();
        assert_eq!(r.iterations, 5);
        assert!(!r.converged);
    }
}
