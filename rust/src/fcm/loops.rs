//! Outer iteration loops: weighted FCM (fast or classic chunk math) and
//! Lloyd's K-Means, generic over the chunk backend — plus the
//! **iteration-resident distributed loop** ([`run_fcm_session`]), where
//! each iteration is one MapReduce job over a block store run through an
//! [`crate::mapreduce::IterativeSession`]: job startup charged once, warm
//! block cache and prefetcher across iterations, worker-side tree combine
//! of the per-block [`Partials`], and shift-bounded pruning against the
//! session's sticky per-block state slab.
//!
//! Layer 3 owns these loops by design — the AOT artifacts only compute one
//! pass of partials, so convergence policy (epsilon on the max squared
//! center shift, iteration cap) lives here in rust, identical for the
//! native and PJRT backends.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::data::Matrix;
use crate::error::{Error, Result};
use crate::fcm::backend::{BlockBounds, BoundConfig, BoundModel, Kernel, KernelBackend, QuantMode};
use crate::fcm::checkpoint::SessionCheckpoint;
use crate::fcm::{max_center_shift2, ClusterResult, Partials};
use crate::hdfs::BlockStore;
use crate::mapreduce::shard::complete_global_dag;
use crate::mapreduce::{
    DistributedCache, Engine, JobStats, MapReduceJob, SessionOptions, ShardMergeMode,
    ShardedEngine, SimCost, SlabState, SpillConfig, StateSlab, TaskCtx, MIB,
};
use crate::telemetry::metrics::MetricsRegistry;
use crate::telemetry::trace;

/// FCM chunk-math variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Kolen–Hutcheson O(n·c) update (paper Algorithm 1).
    Fast,
    /// Textbook O(n·c²) update ("basic FCM").
    Classic,
}

impl std::str::FromStr for Variant {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "fast" => Ok(Variant::Fast),
            "classic" => Ok(Variant::Classic),
            other => Err(Error::InvalidArgument(format!(
                "unknown variant `{other}` (fast|classic)"
            ))),
        }
    }
}

/// Parameters of one FCM run (paper notation).
#[derive(Clone, Copy, Debug)]
pub struct FcmParams {
    /// Fuzzifier m > 1.
    pub m: f64,
    /// Convergence threshold on max squared center shift.
    pub epsilon: f64,
    /// Iteration cap (paper uses 1000).
    pub max_iterations: usize,
    /// Chunk-math variant.
    pub variant: Variant,
}

impl Default for FcmParams {
    fn default() -> Self {
        Self { m: 2.0, epsilon: 5.0e-7, max_iterations: 1000, variant: Variant::Fast }
    }
}

fn one_pass(
    backend: &dyn KernelBackend,
    x: &Matrix,
    v: &Matrix,
    w: &[f32],
    params: &FcmParams,
) -> Result<Partials> {
    // Variant::Classic takes the fused (pair-loop-free) classic kernel;
    // the O(C²) pair loop is reserved for the Mahout baseline model
    // (`Kernel::FcmClassicPair`, `crate::baselines`).
    match params.variant {
        Variant::Fast => backend.fcm_partials(x, v, w, params.m),
        Variant::Classic => backend.classic_partials(x, v, w, params.m),
    }
}

/// Weighted FCM to convergence over in-memory records.
///
/// This is the paper's Algorithm 1 (WFCM): each iteration computes weighted
/// membership terms and center numerators in one pass, then divides. The
/// final per-center weights (Σ u^m w) are returned as the center importance
/// used by downstream WFCM merges (paper Eq. 6).
pub fn run_fcm(
    backend: &dyn KernelBackend,
    x: &Matrix,
    w: &[f32],
    v0: Matrix,
    params: &FcmParams,
) -> Result<ClusterResult> {
    if x.rows() == 0 {
        return Err(Error::Clustering("empty input".into()));
    }
    if x.rows() != w.len() {
        return Err(Error::Clustering(format!(
            "weights length {} != rows {}",
            w.len(),
            x.rows()
        )));
    }
    if v0.cols() != x.cols() {
        return Err(Error::Clustering("seed center dims mismatch".into()));
    }
    let mut v = v0;
    let mut weights = vec![0.0; v.rows()];
    let mut objective = f64::INFINITY;
    let mut converged = false;
    let mut iterations = 0;
    for it in 1..=params.max_iterations {
        iterations = it;
        let partials = one_pass(backend, x, &v, w, params)?;
        weights.clone_from_slice(&partials.w_acc);
        objective = partials.objective;
        let v_new = partials.into_centers(&v);
        let shift = max_center_shift2(&v, &v_new);
        v = v_new;
        if shift <= params.epsilon {
            converged = true;
            break;
        }
    }
    Ok(ClusterResult { centers: v, weights, iterations, objective, converged })
}

/// Lloyd's K-Means to convergence (the Mahout-KM compute model).
pub fn kmeans_loop(
    backend: &dyn KernelBackend,
    x: &Matrix,
    v0: Matrix,
    epsilon: f64,
    max_iterations: usize,
) -> Result<ClusterResult> {
    if x.rows() == 0 {
        return Err(Error::Clustering("empty input".into()));
    }
    let w = vec![1.0f32; x.rows()];
    let mut v = v0;
    let mut weights = vec![0.0; v.rows()];
    let mut objective = f64::INFINITY;
    let mut converged = false;
    let mut iterations = 0;
    for it in 1..=max_iterations {
        iterations = it;
        let partials = backend.kmeans_partials(x, &v, &w)?;
        weights.clone_from_slice(&partials.w_acc);
        objective = partials.objective;
        let v_new = partials.into_centers(&v);
        let shift = max_center_shift2(&v, &v_new);
        v = v_new;
        if shift <= epsilon {
            converged = true;
            break;
        }
    }
    Ok(ClusterResult { centers: v, weights, iterations, objective, converged })
}

// ---------------------------------------------------------------------------
// Iteration-resident distributed loop
// ---------------------------------------------------------------------------

/// Pruning knobs of an iteration-resident session run.
#[derive(Clone, Debug)]
pub struct PruneConfig {
    /// Master switch; disabled sessions run every pass exactly.
    pub enabled: bool,
    /// Bound model the sticky state maintains (`cluster.bounds`): `DMin`
    /// is the single nearest-center bound, `Elkan` the per-record ×
    /// per-center bounds that keep pruning through mid-shift iterations.
    pub bounds: BoundModel,
    /// Relative distance-perturbation tolerance: a record replays its
    /// cached contribution while each center's accumulated shift stays
    /// below `tolerance ×` its bound.
    pub tolerance: f64,
    /// Force an exact (bound-refreshing) pass at least every this many
    /// passes — the drift bound (the *base* cap when
    /// [`Self::adaptive_refresh`] is on).
    pub refresh_every: usize,
    /// Scale the drift cap by the observed per-iteration shift trajectory
    /// (`cluster.adaptive_refresh`, ROADMAP iteration-residency item):
    /// while the max center shift keeps shrinking geometrically the cap
    /// doubles (up to 8× the base — late iterations barely move the
    /// bounds, so periodic refreshes there are pure overhead), and any
    /// shift growth snaps it back to the base. The per-center tolerance
    /// test stays in force at every staleness, so the cap only trades
    /// refresh cadence, never bound soundness.
    pub adaptive_refresh: bool,
    /// Quantized distance pre-pass (`cluster.quant`): when enabled, each
    /// cached block carries a one-time i8 sidecar whose certified error
    /// radius gives records the bound tests abandon a second chance to
    /// replay — exact math runs only for records neither test certifies.
    pub quant: QuantMode,
    /// Sticky-slab byte budget (see `cluster.slab_mib`).
    pub slab_bytes: u64,
    /// Disk spill ring for cold slab state (`cluster.slab_spill_dir`);
    /// `None` evicts under budget pressure instead.
    pub spill_dir: Option<PathBuf>,
}

impl Default for PruneConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            bounds: BoundModel::Elkan,
            tolerance: 5e-3,
            refresh_every: 4,
            adaptive_refresh: true,
            quant: QuantMode::Off,
            slab_bytes: 64 * MIB,
            spill_dir: None,
        }
    }
}

impl PruneConfig {
    /// The exact control arm: no pruning, no slab.
    pub fn disabled() -> Self {
        Self { enabled: false, ..Default::default() }
    }

    /// The PR-3 single-bound arm (the A/B control of the elkan default).
    pub fn dmin() -> Self {
        Self { bounds: BoundModel::DMin, ..Default::default() }
    }

    /// Budget, bound model and spill ring from the cluster config.
    pub fn from_cluster(cluster: &crate::config::ClusterConfig) -> Self {
        let spill_dir = if cluster.slab_spill_dir.is_empty() {
            None
        } else {
            Some(PathBuf::from(&cluster.slab_spill_dir))
        };
        Self {
            slab_bytes: cluster.slab_mib as u64 * MIB,
            bounds: cluster.bounds,
            adaptive_refresh: cluster.adaptive_refresh,
            quant: cluster.quant,
            spill_dir,
            ..Default::default()
        }
    }

    /// The per-pass knobs handed to [`KernelBackend::pruned_partials`].
    pub fn bound_cfg(&self) -> BoundConfig {
        BoundConfig {
            model: self.bounds,
            tolerance: self.tolerance,
            refresh_every: self.refresh_every,
            quant: self.quant,
        }
    }
}

/// Periodic checkpointing of an iteration-resident session (the recovery
/// half of the chaos layer; see [`crate::fcm::checkpoint`]).
#[derive(Clone, Debug)]
pub struct CheckpointPolicy {
    /// Write a checkpoint after every this many completed iterations
    /// (`session.checkpoint_every`; 0 disables even when a path is set).
    pub every: usize,
    /// Checkpoint file, overwritten in place each time — a resume only
    /// ever wants the newest state, and the checksum trailer catches a
    /// torn overwrite.
    pub path: PathBuf,
}

/// Which per-iteration partials the session loop computes. The FCM arm
/// takes its Fast/Classic chunk math from [`FcmParams::variant`], exactly
/// like [`run_fcm`] — one source of truth, no redundant specification.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionAlgo {
    /// Weighted FCM ([`FcmParams::variant`] picks the chunk math).
    Fcm,
    /// Lloyd's K-Means.
    KMeans,
}

impl SessionAlgo {
    /// The (algo, variant) choice collapsed onto the backend's dispatch
    /// token — the one place the mapping exists (the session loop and the
    /// serving layer's [`crate::serve::ModelBundle`] both dispatch through
    /// it).
    pub fn kernel(&self, variant: Variant) -> Kernel {
        match (self, variant) {
            (SessionAlgo::Fcm, Variant::Fast) => Kernel::FcmFast,
            (SessionAlgo::Fcm, Variant::Classic) => Kernel::FcmClassic,
            (SessionAlgo::KMeans, _) => Kernel::KMeans,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SessionAlgo::Fcm => "fcm",
            SessionAlgo::KMeans => "kmeans",
        }
    }
}

impl std::str::FromStr for SessionAlgo {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "fcm" => Ok(SessionAlgo::Fcm),
            "km" | "kmeans" => Ok(SessionAlgo::KMeans),
            other => Err(Error::InvalidArgument(format!(
                "unknown session algo `{other}` (fcm|kmeans)"
            ))),
        }
    }
}

/// Distributed-cache key the session loop publishes the centers under
/// (overwritten in place each iteration — the cache itself is resident).
const KEY_SESSION_CENTERS: &str = "session_centers";

/// The per-iteration job: one pass of partials for every block against the
/// current centers, pruned against the session's sticky slab, merged
/// pairwise on the pool (tree combine) on the way to the reduce. Dispatch
/// is one [`Kernel`] token through the object-safe [`KernelBackend`] — no
/// per-variant match arms, so the same job drives native, PJRT and the
/// shim.
struct SessionPartialsJob {
    kernel: Kernel,
    m: f64,
    backend: Arc<dyn KernelBackend>,
    slab: Arc<StateSlab<BlockBounds>>,
    prune: PruneConfig,
    bound_cfg: BoundConfig,
    /// Effective refresh cap of the *next* pass — the session loop's
    /// adaptive-refresh policy writes it between iterations (map tasks
    /// only read it), overriding `bound_cfg.refresh_every`.
    refresh_cap: AtomicUsize,
    /// Shared all-ones weight buffer, grown on demand — per-task weight
    /// allocation would put an O(rows) memset on the whole-block pruned
    /// path, whose entire point is to touch no record.
    ones: Mutex<Arc<Vec<f32>>>,
}

impl SessionPartialsJob {
    fn new(
        kernel: Kernel,
        m: f64,
        backend: Arc<dyn KernelBackend>,
        slab: Arc<StateSlab<BlockBounds>>,
        prune: PruneConfig,
    ) -> Self {
        let bound_cfg = prune.bound_cfg();
        let refresh_cap = AtomicUsize::new(bound_cfg.refresh_every);
        Self {
            kernel,
            m,
            backend,
            slab,
            prune,
            bound_cfg,
            refresh_cap,
            ones: Mutex::new(Arc::new(Vec::new())),
        }
    }

    /// Set the refresh cap the next iteration's pruned passes run under.
    fn set_refresh_cap(&self, cap: usize) {
        self.refresh_cap.store(cap, Ordering::Relaxed);
    }

    /// All-ones weights of at least `n` entries (callers slice to size).
    fn uniform_weights(&self, n: usize) -> Arc<Vec<f32>> {
        let mut buf = self.ones.lock().expect("weights buffer poisoned");
        if buf.len() < n {
            *buf = Arc::new(vec![1.0f32; n]);
        }
        Arc::clone(&buf)
    }
}

impl MapReduceJob for SessionPartialsJob {
    type MapOut = Partials;
    type Output = Partials;

    fn map_combine(&self, block: &Matrix, ctx: &TaskCtx) -> Result<Partials> {
        let v = ctx
            .cache
            .get_matrix(KEY_SESSION_CENTERS)
            .ok_or_else(|| Error::Job("session centers missing from cache".into()))?;
        let ones = self.uniform_weights(block.rows());
        let w = &ones[..block.rows()];
        // Doomed and retried attempts (injected-fault re-execution) bypass
        // the slab entirely: the engine's combiner contract is idempotence,
        // and a discarded attempt must neither advance the sticky state nor
        // inflate `records_pruned` with replays whose output is thrown
        // away. An exact pass is always safe and retries are the rare case
        // by construction.
        if !self.prune.enabled || ctx.attempt > 0 || ctx.doomed {
            return self.backend.exact_partials(self.kernel, block, &v, w, self.m);
        }
        let bound_cfg = BoundConfig {
            refresh_every: self.refresh_cap.load(Ordering::Relaxed),
            ..self.bound_cfg
        };
        let handle = self.slab.entry(ctx.task_id);
        let mut st = handle.lock().expect("slab state poisoned");
        let (p, pstats) = self.backend.pruned_partials(
            self.kernel,
            block,
            &v,
            w,
            self.m,
            &mut st,
            &bound_cfg,
        )?;
        let bytes = st.slab_bytes();
        drop(st); // never hold a state lock while taking the slab lock
        self.slab.note_update(ctx.task_id, &handle, bytes);
        if pstats.pruned > 0 {
            self.slab.add_records_pruned(pstats.pruned as u64);
        }
        if pstats.quant > 0 {
            self.slab.add_records_pruned_quant(pstats.quant as u64);
        }
        if pstats.sidecar_bytes > 0 {
            self.slab.add_quant_sidecar_bytes(pstats.sidecar_bytes);
        }
        if pstats.sidecar_build_s > 0.0 {
            self.slab.add_quant_build_ns((pstats.sidecar_build_s * 1e9) as u64);
        }
        Ok(p)
    }

    fn reduce(&self, parts: Vec<Partials>, _ctx: &TaskCtx) -> Result<Partials> {
        let mut it = parts.into_iter();
        let mut acc = it
            .next()
            .ok_or_else(|| Error::Job("no partials to reduce".into()))?;
        for p in it {
            acc.merge(&p);
        }
        Ok(acc)
    }

    fn supports_combine(&self) -> bool {
        true
    }

    fn combine(&self, mut left: Partials, right: Partials) -> Result<Partials> {
        left.merge(&right);
        Ok(left)
    }

    fn shuffle_bytes(&self, part: &Partials) -> u64 {
        part.encoded_bytes()
    }

    fn name(&self) -> &str {
        match self.kernel {
            Kernel::FcmFast => "session-fcm-fast",
            Kernel::FcmClassic | Kernel::FcmClassicPair => "session-fcm-classic",
            Kernel::KMeans => "session-kmeans",
        }
    }
}

/// Outcome of an iteration-resident convergence run.
#[derive(Clone, Debug)]
pub struct SessionRunResult {
    /// Final centers / weights / convergence record.
    pub result: ClusterResult,
    /// Engine jobs run (= iterations; startup charged once when resident).
    pub jobs: usize,
    /// Map records served from the sticky slab across the whole run.
    pub records_pruned: u64,
    /// Subset of `records_pruned` certified by the quantized pre-pass
    /// after the primary bound test gave up (0 with `cluster.quant=off`).
    pub records_pruned_quant: u64,
    /// Peak per-iteration quant-sidecar footprint across the run.
    pub quant_sidecar_bytes: u64,
    /// Total real seconds spent building quant sidecars (one-time per
    /// block; all of it lands in the first quant-enabled iteration).
    pub quant_build_s: f64,
    /// Bytes the slab wrote to its disk spill ring across the run.
    pub slab_spilled_bytes: u64,
    /// Slab states reloaded from the spill ring across the run.
    pub slab_reloads: u64,
    /// Transient-fault retries taken by spill-ring slot reads across the
    /// run (chaos runs only).
    pub slab_spill_retries: u64,
    /// Checksum-quarantine re-reads of spill-ring slots across the run
    /// (chaos runs only).
    pub slab_spill_quarantines: u64,
    /// Session checkpoints written across the run (0 without a
    /// [`CheckpointPolicy`]).
    pub checkpoints_written: u64,
    /// Total checkpoint bytes written — the recovery-overhead figure of
    /// the fault-tolerance experiments table.
    pub checkpoint_bytes: u64,
    /// Per-iteration job stats, with `records_pruned`, `slab_bytes` and
    /// `slab_evictions` stamped in.
    pub per_iteration: Vec<JobStats>,
    /// Max of the block cache's per-iteration peak resident bytes across
    /// the whole loop (the session resets the per-job meters between
    /// iterations, so a single post-loop gauge read would only see the
    /// last one — envelope checks must use this).
    pub peak_resident_bytes: u64,
    /// This run's share of the modelled cluster cost.
    pub sim: SimCost,
}

impl SessionRunResult {
    /// Publish this run into `reg`: `session.*` for the run-level
    /// counters and `job.*` for the per-iteration [`JobStats`] rows summed
    /// across the run. Counters carry exact integers, so the registry is a
    /// bit-identical view of the legacy struct — the CLI report, bench
    /// JSON and wire exposition all read these names instead of
    /// re-deriving their own totals.
    pub fn publish_metrics(&self, reg: &MetricsRegistry) {
        reg.set_counter("session.jobs", self.jobs as u64);
        reg.set_counter("session.iterations", self.result.iterations as u64);
        reg.set_counter("session.records_pruned", self.records_pruned);
        reg.set_counter("session.records_pruned_quant", self.records_pruned_quant);
        reg.set_counter("session.quant_sidecar_bytes", self.quant_sidecar_bytes);
        reg.set_counter("session.slab_spilled_bytes", self.slab_spilled_bytes);
        reg.set_counter("session.slab_reloads", self.slab_reloads);
        reg.set_counter("session.slab_spill_retries", self.slab_spill_retries);
        reg.set_counter("session.slab_spill_quarantines", self.slab_spill_quarantines);
        reg.set_counter("session.checkpoints_written", self.checkpoints_written);
        reg.set_counter("session.checkpoint_bytes", self.checkpoint_bytes);
        reg.set_counter("session.peak_resident_bytes", self.peak_resident_bytes);
        reg.set_gauge("session.converged", if self.result.converged { 1.0 } else { 0.0 });
        reg.set_gauge("session.objective", self.result.objective);
        reg.set_gauge("session.quant_build_s", self.quant_build_s);
        reg.set_gauge("session.sim_total_s", self.sim.total_s());
        reg.set_gauge("session.sim_backoff_s", self.sim.backoff_s);
        let sum = self.per_iteration.iter().fold(JobStats::default(), |mut acc, s| {
            acc.wall += s.wall;
            acc.map_tasks += s.map_tasks;
            acc.attempts += s.attempts;
            acc.shuffle_bytes += s.shuffle_bytes;
            acc.locality_hits += s.locality_hits;
            acc.locality_steals += s.locality_steals;
            acc.prefetch_hits += s.prefetch_hits;
            acc.prefetch_wasted_bytes += s.prefetch_wasted_bytes;
            acc.read_retries += s.read_retries;
            acc.read_aborts += s.read_aborts;
            acc.quarantines += s.quarantines;
            acc.prefetch_errors += s.prefetch_errors;
            acc.records_pruned += s.records_pruned;
            acc.records_pruned_quant += s.records_pruned_quant;
            acc.quant_sidecar_bytes = acc.quant_sidecar_bytes.max(s.quant_sidecar_bytes);
            acc.quant_build_s += s.quant_build_s;
            acc.slab_bytes = acc.slab_bytes.max(s.slab_bytes);
            acc.slab_evictions = acc.slab_evictions.max(s.slab_evictions);
            acc.slab_spilled_bytes = acc.slab_spilled_bytes.max(s.slab_spilled_bytes);
            acc.slab_reloads = acc.slab_reloads.max(s.slab_reloads);
            acc.slab_spill_retries = acc.slab_spill_retries.max(s.slab_spill_retries);
            acc.slab_spill_quarantines =
                acc.slab_spill_quarantines.max(s.slab_spill_quarantines);
            acc.refresh_cap = acc.refresh_cap.max(s.refresh_cap);
            acc.shard_steals += s.shard_steals;
            acc.shard_steal_bytes += s.shard_steal_bytes;
            acc.combine_depth = acc.combine_depth.max(s.combine_depth);
            acc.reduce_parts += s.reduce_parts;
            acc.reduce_wall_s += s.reduce_wall_s;
            acc.combine_wall_s += s.combine_wall_s;
            acc.read_wall_s += s.read_wall_s;
            acc.compute_wall_s += s.compute_wall_s;
            acc.sim.add(&s.sim);
            acc
        });
        sum.publish_metrics(reg, "job");
    }
}

/// Run an FCM (or K-Means) convergence loop over a block store through an
/// iteration-resident session: every iteration is one engine job, but the
/// pool, block cache, prefetcher, distributed cache and the sticky pruning
/// slab stay warm across them, and job startup is charged per
/// [`SessionOptions::resident`].
///
/// With pruning on, a convergence signal read off a pruned pass could be
/// an artifact of frozen contributions, so it is only accepted from an
/// exact pass: the loop invalidates the slab and re-checks on the next
/// (exact) iteration. Final centers therefore always satisfy the epsilon
/// criterion under exact math.
#[allow(clippy::too_many_arguments)]
pub fn run_fcm_session(
    engine: &mut Engine,
    store: &Arc<BlockStore>,
    backend: Arc<dyn KernelBackend>,
    algo: SessionAlgo,
    v0: Matrix,
    params: &FcmParams,
    prune: &PruneConfig,
    options: SessionOptions,
    checkpoint: Option<&CheckpointPolicy>,
) -> Result<SessionRunResult> {
    if v0.cols() != store.cols() {
        return Err(Error::Clustering("seed center dims mismatch".into()));
    }
    if v0.rows() == 0 {
        return Err(Error::Clustering("no seed centers".into()));
    }
    let sim_before = engine.clock().cost();
    let tracer = trace::global();
    let mut session_span = tracer.span("session", "session");
    session_span.attr("algo", algo.as_str().to_string());
    session_span.attr("clusters", v0.rows().to_string());
    // The slab's spill ring sits under the same chaos plan as the engine's
    // block reads: `[faults]` covers every I/O boundary of a session run.
    let fault_plan = engine.options().faults.clone();
    let spill = prune
        .spill_dir
        .as_ref()
        .filter(|_| prune.enabled)
        .map(|dir| SpillConfig::new(dir.clone()).with_faults(fault_plan.clone()));
    let slab = Arc::new(StateSlab::new(
        if prune.enabled { prune.slab_bytes } else { 0 },
        spill,
    ));
    let job = Arc::new(SessionPartialsJob::new(
        algo.kernel(params.variant),
        params.m,
        backend,
        Arc::clone(&slab),
        prune.clone(),
    ));
    let mut session = engine.session(store, options);
    let cache = Arc::new(DistributedCache::new());

    let mut v = v0;
    let mut weights = vec![0.0; v.rows()];
    let mut objective = f64::INFINITY;
    let mut converged = false;
    let mut iterations = 0usize;
    let mut records_pruned_total = 0u64;
    let mut records_pruned_quant_total = 0u64;
    let mut quant_sidecar_peak = 0u64;
    let mut quant_build_s_total = 0.0f64;
    let mut peak_resident_bytes = 0u64;
    let mut spill_io_charged = 0u64;
    let mut slab_backoff_charged = 0.0f64;
    let mut checkpoints_written = 0u64;
    let mut checkpoint_bytes = 0u64;
    let mut per_iteration: Vec<JobStats> = Vec::new();
    // Adaptive refresh cap (ROADMAP iteration-residency item): while the
    // shift trajectory keeps shrinking geometrically the cap doubles (up
    // to 8× the base), so settled tails are not interrupted by periodic
    // refreshes; any shift growth snaps it back to the configured base.
    let base_cap = prune.refresh_every.max(1);
    let mut refresh_cap = base_cap;
    let mut shrink_streak = 0usize;
    let mut prev_shift = f64::INFINITY;
    for it in 1..=params.max_iterations {
        iterations = it;
        let mut iter_span = tracer.span("iteration", "session");
        iter_span.attr("iteration", it.to_string());
        cache.put_matrix(KEY_SESSION_CENTERS, v.clone());
        let (partials, mut stats) = session.run_iteration(Arc::clone(&job), Arc::clone(&cache))?;
        let pruned_this = slab.take_records_pruned();
        let pruned_quant_this = slab.take_records_pruned_quant();
        let sidecar_bytes_this = slab.take_quant_sidecar_bytes();
        let quant_build_s_this = slab.take_quant_build_ns() as f64 * 1e-9;
        stats.refresh_cap = refresh_cap;
        stats.records_pruned = pruned_this;
        stats.records_pruned_quant = pruned_quant_this;
        stats.quant_sidecar_bytes = sidecar_bytes_this;
        stats.quant_build_s = quant_build_s_this;
        stats.slab_bytes = slab.bytes();
        stats.slab_evictions = slab.evictions();
        stats.slab_spilled_bytes = slab.spilled_bytes();
        stats.slab_reloads = slab.reloads();
        stats.slab_spill_retries = slab.spill_retries();
        stats.slab_spill_quarantines = slab.spill_quarantines();
        // Stamp the reported wall onto the trace span so the Chrome rows
        // agree with `JobStats` exactly (same number, one source).
        iter_span.set_dur(stats.wall);
        iter_span.attr("pruned", pruned_this.to_string());
        records_pruned_total += pruned_this;
        records_pruned_quant_total += pruned_quant_this;
        quant_sidecar_peak = quant_sidecar_peak.max(sidecar_bytes_this);
        quant_build_s_total += quant_build_s_this;
        // Spill writes and reloads are real disk transfers: charge this
        // iteration's delta to the modelled clock at the HDFS rate (the
        // reread side of the slab's recompute-vs-reread crossover; the
        // recompute side shows up as kernel compute when a bound is gone).
        let spill_io = slab.spilled_bytes() + slab.reload_bytes();
        if spill_io > spill_io_charged {
            session.charge_scan(spill_io - spill_io_charged);
            spill_io_charged = spill_io;
        }
        // Modelled retry backoff the ring's recovered reads accrued inside
        // map tasks: fold each iteration's delta into the clock exactly
        // once (the block cache's own backoff is already folded per job).
        let slab_backoff = slab.backoff_seconds();
        if slab_backoff > slab_backoff_charged {
            session.charge_backoff(slab_backoff - slab_backoff_charged);
            slab_backoff_charged = slab_backoff;
        }
        // The per-job meters reset between iterations; fold each
        // iteration's peak into the loop-wide envelope figure.
        peak_resident_bytes =
            peak_resident_bytes.max(session.engine().block_cache().peak_resident_bytes());
        weights.clone_from_slice(&partials.w_acc);
        objective = partials.objective;
        let v_new = partials.into_centers(&v);
        let shift = max_center_shift2(&v, &v_new);
        v = v_new;
        if prune.enabled && prune.adaptive_refresh {
            if shift <= 0.5 * prev_shift {
                shrink_streak += 1;
                if shrink_streak >= 2 {
                    refresh_cap = (refresh_cap * 2).min(base_cap * 8);
                }
            } else {
                shrink_streak = 0;
                if shift > prev_shift {
                    refresh_cap = base_cap;
                }
            }
            job.set_refresh_cap(refresh_cap);
        }
        prev_shift = shift;
        per_iteration.push(stats);
        if let Some(cp) = checkpoint {
            if cp.every > 0 && it % cp.every == 0 {
                let written = SessionCheckpoint {
                    algo,
                    variant: params.variant,
                    iteration: it as u64,
                    objective,
                    m: params.m,
                    centers: v.clone(),
                    weights: weights.clone(),
                }
                .save(&cp.path)?;
                checkpoints_written += 1;
                checkpoint_bytes += written;
                // A checkpoint is a real disk transfer — charge it like
                // the spill ring's, so recovery overhead shows up in sim.
                session.charge_scan(written);
            }
        }
        if shift <= params.epsilon {
            if prune.enabled && pruned_this > 0 {
                // Confirm convergence with an exact pass: drop every
                // cached bound so the next iteration recomputes fully.
                slab.invalidate_all();
                continue;
            }
            converged = true;
            break;
        }
    }
    drop(session);

    // Report only this run's share when the engine is reused.
    let sim = engine.clock().cost().delta(&sim_before);

    Ok(SessionRunResult {
        result: ClusterResult { centers: v, weights, iterations, objective, converged },
        jobs: iterations,
        records_pruned: records_pruned_total,
        records_pruned_quant: records_pruned_quant_total,
        quant_sidecar_bytes: quant_sidecar_peak,
        quant_build_s: quant_build_s_total,
        slab_spilled_bytes: slab.spilled_bytes(),
        slab_reloads: slab.reloads(),
        slab_spill_retries: slab.spill_retries(),
        slab_spill_quarantines: slab.spill_quarantines(),
        checkpoints_written,
        checkpoint_bytes,
        per_iteration,
        peak_resident_bytes,
        sim,
    })
}

// ---------------------------------------------------------------------------
// Sharded iteration-resident loop
// ---------------------------------------------------------------------------

/// Outcome of a sharded iteration-resident run: the merged
/// [`SessionRunResult`] plus the per-shard view the scaling experiments
/// read — per-shard pruning, per-shard cache envelopes, rack traffic, and
/// the representative-merge quality delta.
#[derive(Clone, Debug)]
pub struct ShardedSessionRunResult {
    /// Merged run view. Per-iteration rows are the merged shard rows:
    /// counters summed, wall = max over shards + the global merge stage,
    /// modelled time = critical shard + per-shard startups + globals.
    pub run: SessionRunResult,
    /// Shard count the run actually used (the plan clamps to the block
    /// count, so this can be lower than `cluster.shards`).
    pub shards: usize,
    /// Merge mode the global stage ran.
    pub merge: ShardMergeMode,
    /// Map records served from each shard's sticky slab across the run.
    pub records_pruned_per_shard: Vec<u64>,
    /// Max per-iteration peak resident bytes of each shard's block cache
    /// — the per-shard memory-envelope figure.
    pub per_shard_peak_resident_bytes: Vec<u64>,
    /// Final iteration's per-shard stats rows (slab counters stamped).
    pub per_shard_last: Vec<JobStats>,
    /// Blocks the plan-time rebalance moved across shards.
    pub shard_steals: usize,
    /// Serialised bytes of those blocks (charged to `net_s` once, on the
    /// cold first iteration, at `shard.steal_penalty ×` the wire rate).
    pub shard_steal_bytes: u64,
    /// Final iteration's objective-weighted squared distance between the
    /// representative merge's centers and the exact merge's
    /// (`Σ_i w_i ‖c_rep,i − c_exact,i‖²`; 0 under `shard.merge = exact`).
    pub merge_objective_delta: f64,
    /// Max of that delta across the run.
    pub merge_objective_delta_max: f64,
}

/// The representative exchange (à la Bendechache et al., arXiv
/// 1710.09593): each shard ships only its local centers + fuzzy counts,
/// and the driver reconstructs global numerators as `Σ_s c_s,i · w_s,i`.
/// Exact when every shard's per-cluster mean agrees; otherwise a measured
/// approximation — the caller records the delta vs the exact merge.
fn representative_merge(shard_parts: &[Partials], fallback: &Matrix) -> Partials {
    let (c, d) = (fallback.rows(), fallback.cols());
    let mut out = Partials::zeros(c, d);
    for p in shard_parts {
        let centers = p.clone().into_centers(fallback);
        for i in 0..c {
            let w = p.w_acc[i];
            out.w_acc[i] += w;
            for j in 0..d {
                let cur = out.v_num.get(i, j);
                out.v_num.set(i, j, cur + (centers.get(i, j) as f64 * w) as f32);
            }
        }
        out.objective += p.objective;
    }
    out
}

/// [`run_fcm_session`] across N engine shards (see
/// [`crate::mapreduce::shard`]): every iteration maps + locally combines
/// on each shard's own pool/cache/prefetcher/slab concurrently, then a
/// driver-side global stage merges the per-shard outputs — either
/// completing the exact merge DAG (bitwise drop-in for the single-engine
/// loop) or through the representative centers-only exchange, whose
/// objective-quality delta vs exact is measured every iteration.
///
/// Bounds state, quant sidecars and warm blocks stay **shard-resident**:
/// each shard owns a slab keyed by global block ids (the id spaces
/// partition, so a shared spill dir never collides), sized at
/// `slab_bytes / shards`.
#[allow(clippy::too_many_arguments)]
pub fn run_fcm_session_sharded(
    engine: &mut ShardedEngine,
    store: &Arc<BlockStore>,
    backend: Arc<dyn KernelBackend>,
    algo: SessionAlgo,
    v0: Matrix,
    params: &FcmParams,
    prune: &PruneConfig,
    options: SessionOptions,
    checkpoint: Option<&CheckpointPolicy>,
    merge: ShardMergeMode,
) -> Result<ShardedSessionRunResult> {
    if v0.cols() != store.cols() {
        return Err(Error::Clustering("seed center dims mismatch".into()));
    }
    if v0.rows() == 0 {
        return Err(Error::Clustering("no seed centers".into()));
    }
    let shards = engine.shards();
    let sim_before = engine.clock().cost();
    let tracer = trace::global();
    let mut session_span = tracer.span("session", "session");
    session_span.attr("algo", algo.as_str().to_string());
    session_span.attr("shards", shards.to_string());
    let slab_budget = if prune.enabled { (prune.slab_bytes / shards as u64).max(1) } else { 0 };
    let slabs: Vec<Arc<StateSlab<BlockBounds>>> = (0..shards)
        .map(|i| {
            // Each shard's spill ring sits under that shard's derived
            // fault domain, like its block reads.
            let spill = prune.spill_dir.as_ref().filter(|_| prune.enabled).map(|dir| {
                SpillConfig::new(dir.clone())
                    .with_faults(engine.engine(i).options().faults.clone())
            });
            Arc::new(StateSlab::new(slab_budget, spill))
        })
        .collect();
    let jobs: Vec<Arc<SessionPartialsJob>> = slabs
        .iter()
        .map(|slab| {
            Arc::new(SessionPartialsJob::new(
                algo.kernel(params.variant),
                params.m,
                Arc::clone(&backend),
                Arc::clone(slab),
                prune.clone(),
            ))
        })
        .collect();
    let total_blocks = engine.plan().total_blocks;
    let shard_steals = engine.plan().steals();
    let shard_steal_bytes = engine.plan().steal_bytes();
    let mut session = engine.session(store, options);
    let cache = Arc::new(DistributedCache::new());

    let mut v = v0;
    let mut weights = vec![0.0; v.rows()];
    let mut objective = f64::INFINITY;
    let mut converged = false;
    let mut iterations = 0usize;
    let mut records_pruned_total = 0u64;
    let mut records_pruned_quant_total = 0u64;
    let mut quant_sidecar_peak = 0u64;
    let mut quant_build_s_total = 0.0f64;
    let mut records_pruned_per_shard = vec![0u64; shards];
    let mut per_shard_peak = vec![0u64; shards];
    let mut per_shard_last: Vec<JobStats> = Vec::new();
    let mut spill_io_charged = vec![0u64; shards];
    let mut backoff_charged = vec![0.0f64; shards];
    let mut checkpoints_written = 0u64;
    let mut checkpoint_bytes = 0u64;
    let mut per_iteration: Vec<JobStats> = Vec::new();
    let mut delta_last = 0.0f64;
    let mut delta_max = 0.0f64;
    let base_cap = prune.refresh_every.max(1);
    let mut refresh_cap = base_cap;
    let mut shrink_streak = 0usize;
    let mut prev_shift = f64::INFINITY;
    for it in 1..=params.max_iterations {
        iterations = it;
        let mut iter_span = tracer.span("iteration", "session");
        iter_span.attr("iteration", it.to_string());
        cache.put_matrix(KEY_SESSION_CENTERS, v.clone());
        let (segments, mut shard_stats, cfg) = session.run_iteration_segments(&jobs, &cache)?;
        // Drain each shard's slab counters into its own stats row — the
        // merged row sums them, and the per-shard rows are the scaling
        // experiments' per-rack truth.
        let mut pruned_this = 0u64;
        let mut sidecar_this = 0u64;
        for (i, (slab, st)) in slabs.iter().zip(shard_stats.iter_mut()).enumerate() {
            let pruned = slab.take_records_pruned();
            let pruned_quant = slab.take_records_pruned_quant();
            let sidecar_bytes = slab.take_quant_sidecar_bytes();
            let build_s = slab.take_quant_build_ns() as f64 * 1e-9;
            st.refresh_cap = refresh_cap;
            st.records_pruned = pruned;
            st.records_pruned_quant = pruned_quant;
            st.quant_sidecar_bytes = sidecar_bytes;
            st.quant_build_s = build_s;
            st.slab_bytes = slab.bytes();
            st.slab_evictions = slab.evictions();
            st.slab_spilled_bytes = slab.spilled_bytes();
            st.slab_reloads = slab.reloads();
            st.slab_spill_retries = slab.spill_retries();
            st.slab_spill_quarantines = slab.spill_quarantines();
            pruned_this += pruned;
            sidecar_this += sidecar_bytes;
            records_pruned_per_shard[i] += pruned;
            records_pruned_quant_total += pruned_quant;
            quant_build_s_total += build_s;
            // Spill writes/reloads and retry backoff are real transfers:
            // fold each shard's delta into the global clock exactly once.
            let spill_io = slab.spilled_bytes() + slab.reload_bytes();
            if spill_io > spill_io_charged[i] {
                session.charge_scan(spill_io - spill_io_charged[i]);
                spill_io_charged[i] = spill_io;
            }
            let backoff = slab.backoff_seconds();
            if backoff > backoff_charged[i] {
                session.charge_backoff(backoff - backoff_charged[i]);
                backoff_charged[i] = backoff;
            }
            per_shard_peak[i] = per_shard_peak[i]
                .max(session.engine().engine(i).block_cache().peak_resident_bytes());
        }
        records_pruned_total += pruned_this;
        quant_sidecar_peak = quant_sidecar_peak.max(sidecar_this);
        // The global merge stage — exact DAG completion or the
        // representative exchange.
        let use_tree = cfg.tree_combine;
        let (partials, global_wall, reduce_wall_s, merges, reduce_parts, delta) = match merge {
            ShardMergeMode::Exact => {
                let flat: Vec<_> = segments.into_iter().flatten().collect();
                let t0 = Instant::now();
                let (survivors, merges) =
                    complete_global_dag(jobs[0].as_ref(), flat, total_blocks, use_tree)?;
                let global_wall = t0.elapsed();
                let reduce_parts = survivors.len();
                let t_r = Instant::now();
                let mut itr = survivors.into_iter();
                let mut acc = itr
                    .next()
                    .ok_or_else(|| Error::Job("no partials to reduce".into()))?;
                for p in itr {
                    acc.merge(&p);
                }
                (acc, global_wall, t_r.elapsed().as_secs_f64(), merges, reduce_parts, 0.0)
            }
            ShardMergeMode::Representative => {
                // The quality yardstick: the exact merge, computed
                // driver-side outside the timed/charged window (it ships
                // no modelled bytes — it exists to measure the delta).
                let flat: Vec<_> = segments
                    .iter()
                    .flat_map(|segs| segs.iter().map(|(k, p)| (*k, p.clone())))
                    .collect();
                let (ex_survivors, _) =
                    complete_global_dag(jobs[0].as_ref(), flat, total_blocks, use_tree)?;
                let mut itr = ex_survivors.into_iter();
                let mut exact = itr
                    .next()
                    .ok_or_else(|| Error::Job("no partials to reduce".into()))?;
                for p in itr {
                    exact.merge(&p);
                }
                // The operative path: per-shard local fold (leftmost-block
                // order), then the centers + fuzzy-counts exchange.
                let t0 = Instant::now();
                let shard_parts = segments
                    .into_iter()
                    .map(|mut segs| -> Result<Partials> {
                        segs.sort_by_key(|((level, slot), _)| slot << level);
                        let mut itr = segs.into_iter().map(|(_, p)| p);
                        let mut acc = itr
                            .next()
                            .ok_or_else(|| Error::Job("shard produced no partials".into()))?;
                        for p in itr {
                            acc.merge(&p);
                        }
                        Ok(acc)
                    })
                    .collect::<Result<Vec<_>>>()?;
                let rep = representative_merge(&shard_parts, &v);
                let global_wall = t0.elapsed();
                let w_ex = exact.w_acc.clone();
                let c_ex = exact.into_centers(&v);
                let c_rep = rep.clone().into_centers(&v);
                let mut delta = 0.0f64;
                for i in 0..c_ex.rows() {
                    delta += w_ex[i] * c_rep.row_dist2(i, c_ex.row(i));
                }
                (rep, global_wall, 0.0, shards.saturating_sub(1), shards, delta)
            }
        };
        delta_last = delta;
        delta_max = delta_max.max(delta);
        let mut merged =
            session.finalize_iteration(&shard_stats, global_wall, reduce_wall_s, merges, reduce_parts);
        merged.refresh_cap = refresh_cap;
        // Same-number contract as the single-engine loop: the iteration
        // span reports exactly the merged row's wall.
        iter_span.set_dur(merged.wall);
        iter_span.attr("pruned", pruned_this.to_string());
        weights.clone_from_slice(&partials.w_acc);
        objective = partials.objective;
        let v_new = partials.into_centers(&v);
        let shift = max_center_shift2(&v, &v_new);
        v = v_new;
        if prune.enabled && prune.adaptive_refresh {
            if shift <= 0.5 * prev_shift {
                shrink_streak += 1;
                if shrink_streak >= 2 {
                    refresh_cap = (refresh_cap * 2).min(base_cap * 8);
                }
            } else {
                shrink_streak = 0;
                if shift > prev_shift {
                    refresh_cap = base_cap;
                }
            }
            for job in &jobs {
                job.set_refresh_cap(refresh_cap);
            }
        }
        prev_shift = shift;
        per_shard_last = shard_stats;
        per_iteration.push(merged);
        if let Some(cp) = checkpoint {
            if cp.every > 0 && it % cp.every == 0 {
                let written = SessionCheckpoint {
                    algo,
                    variant: params.variant,
                    iteration: it as u64,
                    objective,
                    m: params.m,
                    centers: v.clone(),
                    weights: weights.clone(),
                }
                .save(&cp.path)?;
                checkpoints_written += 1;
                checkpoint_bytes += written;
                session.charge_scan(written);
            }
        }
        if shift <= params.epsilon {
            if prune.enabled && pruned_this > 0 {
                // Confirm convergence with an exact pass on every shard.
                for slab in &slabs {
                    slab.invalidate_all();
                }
                continue;
            }
            converged = true;
            break;
        }
    }
    drop(session);

    let sim = engine.clock().cost().delta(&sim_before);
    let peak_resident_bytes = per_shard_peak.iter().copied().max().unwrap_or(0);
    Ok(ShardedSessionRunResult {
        run: SessionRunResult {
            result: ClusterResult { centers: v, weights, iterations, objective, converged },
            jobs: iterations,
            records_pruned: records_pruned_total,
            records_pruned_quant: records_pruned_quant_total,
            quant_sidecar_bytes: quant_sidecar_peak,
            quant_build_s: quant_build_s_total,
            slab_spilled_bytes: slabs.iter().map(|s| s.spilled_bytes()).sum(),
            slab_reloads: slabs.iter().map(|s| s.reloads()).sum(),
            slab_spill_retries: slabs.iter().map(|s| s.spill_retries()).sum(),
            slab_spill_quarantines: slabs.iter().map(|s| s.spill_quarantines()).sum(),
            checkpoints_written,
            checkpoint_bytes,
            per_iteration,
            peak_resident_bytes,
            sim,
        },
        shards,
        merge,
        records_pruned_per_shard,
        per_shard_peak_resident_bytes: per_shard_peak,
        per_shard_last,
        shard_steals,
        shard_steal_bytes,
        merge_objective_delta: delta_last,
        merge_objective_delta_max: delta_max,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;
    use crate::fcm::seeding;
    use crate::fcm::NativeBackend;
    use crate::prng::Pcg;

    #[test]
    fn fcm_recovers_blobs() {
        let data = blobs(600, 3, 3, 0.15, 1);
        let mut rng = Pcg::new(2);
        let v0 = seeding::random_records(&data.features, 3, &mut rng);
        let w = vec![1.0f32; 600];
        let params = FcmParams { epsilon: 1e-10, ..Default::default() };
        let r = run_fcm(&NativeBackend, &data.features, &w, v0, &params).unwrap();
        assert!(r.converged, "did not converge in {} iters", r.iterations);
        // Every found center sits inside some blob (spread 0.15 → within 0.5).
        let truth = crate::fcm::assign_hard(&r.centers, &r.centers);
        assert_eq!(truth.len(), 3);
        for i in 0..3 {
            let mut best = f64::INFINITY;
            for j in 0..600 {
                best = best.min(data.features.row_dist2(j, r.centers.row(i)));
            }
            assert!(best < 0.25, "center {i} far from data: {best}");
        }
    }

    #[test]
    fn objective_monotone_nonincreasing() {
        let data = blobs(400, 4, 3, 0.4, 3);
        let mut rng = Pcg::new(4);
        let v0 = seeding::random_records(&data.features, 3, &mut rng);
        let w = vec![1.0f32; 400];
        let mut v = v0;
        let mut last = f64::INFINITY;
        for _ in 0..10 {
            let p = NativeBackend.fcm_partials(&data.features, &v, &w, 2.0).unwrap();
            assert!(p.objective <= last * (1.0 + 1e-7), "{} > {last}", p.objective);
            last = p.objective;
            v = p.into_centers(&v);
        }
    }

    #[test]
    fn fast_and_classic_converge_to_same_centers() {
        let data = blobs(300, 3, 3, 0.3, 5);
        let mut rng = Pcg::new(6);
        let v0 = seeding::random_records(&data.features, 3, &mut rng);
        let w = vec![1.0f32; 300];
        let fast = run_fcm(
            &NativeBackend,
            &data.features,
            &w,
            v0.clone(),
            &FcmParams { epsilon: 1e-12, variant: Variant::Fast, ..Default::default() },
        )
        .unwrap();
        let classic = run_fcm(
            &NativeBackend,
            &data.features,
            &w,
            v0,
            &FcmParams { epsilon: 1e-12, variant: Variant::Classic, ..Default::default() },
        )
        .unwrap();
        let shift = max_center_shift2(&fast.centers, &classic.centers);
        assert!(shift < 1e-4, "variants diverged: {shift}");
    }

    #[test]
    fn kmeans_recovers_blobs() {
        let data = blobs(600, 3, 3, 0.15, 7);
        let mut rng = Pcg::new(8);
        let v0 = seeding::random_records(&data.features, 3, &mut rng);
        let r = kmeans_loop(&NativeBackend, &data.features, v0, 1e-10, 500).unwrap();
        assert!(r.converged);
        assert!(r.objective / 600.0 < 0.2, "per-record SSE {}", r.objective / 600.0);
    }

    #[test]
    fn weighted_points_pull_centers() {
        // One heavy point at 10 must pull its cluster center toward it.
        let x = Matrix::from_rows(&[vec![0.0], vec![1.0], vec![10.0], vec![11.0]]);
        let w_uniform = vec![1.0f32, 1.0, 1.0, 1.0];
        let w_heavy = vec![1.0f32, 1.0, 50.0, 1.0];
        let v0 = Matrix::from_rows(&[vec![0.5], vec![10.5]]);
        let p = FcmParams { epsilon: 1e-12, ..Default::default() };
        let a = run_fcm(&NativeBackend, &x, &w_uniform, v0.clone(), &p).unwrap();
        let b = run_fcm(&NativeBackend, &x, &w_heavy, v0, &p).unwrap();
        // Heavy cluster center must be closer to 10 than the uniform one.
        let ua = a.centers.get(1, 0);
        let ub = b.centers.get(1, 0);
        assert!((ub - 10.0).abs() < (ua - 10.0).abs(), "{ua} vs {ub}");
    }

    #[test]
    fn rejects_bad_inputs() {
        let x = Matrix::zeros(0, 3);
        let v0 = Matrix::zeros(2, 3);
        assert!(run_fcm(&NativeBackend, &x, &[], v0.clone(), &FcmParams::default()).is_err());
        let x = Matrix::zeros(4, 3);
        assert!(run_fcm(&NativeBackend, &x, &[1.0; 3], v0.clone(), &FcmParams::default()).is_err());
        let v_bad = Matrix::zeros(2, 5);
        assert!(run_fcm(&NativeBackend, &x, &[1.0; 4], v_bad, &FcmParams::default()).is_err());
    }

    #[test]
    fn iteration_cap_respected() {
        let data = blobs(200, 3, 3, 0.4, 9);
        let mut rng = Pcg::new(10);
        let v0 = seeding::random_records(&data.features, 3, &mut rng);
        let w = vec![1.0f32; 200];
        let r = run_fcm(
            &NativeBackend,
            &data.features,
            &w,
            v0,
            &FcmParams { epsilon: 0.0, max_iterations: 5, ..Default::default() },
        )
        .unwrap();
        assert_eq!(r.iterations, 5);
        assert!(!r.converged);
    }

    // -- iteration-resident session loop ---------------------------------

    use crate::config::OverheadConfig;
    use crate::mapreduce::EngineOptions;

    fn session_setup(
        seed: u64,
    ) -> (Arc<BlockStore>, Matrix, FcmParams, Arc<dyn KernelBackend>) {
        let data = blobs(2048, 3, 3, 0.25, seed);
        let store =
            Arc::new(BlockStore::in_memory("t", &data.features, 256, 4).unwrap());
        let mut rng = Pcg::new(seed ^ 0x5E55);
        let v0 = seeding::random_records(&data.features, 3, &mut rng);
        let params = FcmParams { epsilon: 1e-10, ..Default::default() };
        (store, v0, params, Arc::new(NativeBackend))
    }

    #[test]
    fn session_loop_pruned_matches_exact_and_prunes() {
        let (store, v0, params, backend) = session_setup(71);
        let mut exact_engine = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let exact = run_fcm_session(
            &mut exact_engine,
            &store,
            Arc::clone(&backend),
            SessionAlgo::Fcm,
            v0.clone(),
            &params,
            &PruneConfig::disabled(),
            SessionOptions::default(),
            None,
        )
        .unwrap();
        let mut pruned_engine = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let pruned = run_fcm_session(
            &mut pruned_engine,
            &store,
            backend,
            SessionAlgo::Fcm,
            v0,
            &params,
            &PruneConfig::default(),
            SessionOptions::default(),
            None,
        )
        .unwrap();
        assert!(exact.result.converged, "exact arm did not converge");
        assert!(pruned.result.converged, "pruned arm did not converge");
        assert!(exact.records_pruned == 0);
        assert!(
            pruned.records_pruned > 0,
            "tail iterations must prune ({} iterations)",
            pruned.jobs
        );
        let shift = max_center_shift2(&exact.result.centers, &pruned.result.centers);
        assert!(shift < 1e-3, "pruned run drifted from exact: {shift}");
        // Resident session: one job startup for the whole loop.
        let startup = OverheadConfig::default().job_startup_s;
        assert!(
            (pruned.sim.job_startup_s - startup).abs() < 1e-9,
            "resident loop charged startup {} times",
            pruned.sim.job_startup_s / startup
        );
        assert!(pruned.jobs >= 3, "loop should take several iterations");
        // Per-iteration stats carry the slab counters.
        assert!(pruned.per_iteration.iter().any(|s| s.records_pruned > 0));
        assert!(pruned.per_iteration.last().unwrap().slab_bytes > 0);
    }

    #[test]
    fn session_loop_kmeans_matches_exact() {
        let (store, v0, _, backend) = session_setup(81);
        let params = FcmParams { epsilon: 1e-10, ..Default::default() };
        let mut e1 = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let exact = run_fcm_session(
            &mut e1,
            &store,
            Arc::clone(&backend),
            SessionAlgo::KMeans,
            v0.clone(),
            &params,
            &PruneConfig::disabled(),
            SessionOptions::default(),
            None,
        )
        .unwrap();
        let mut e2 = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let pruned = run_fcm_session(
            &mut e2,
            &store,
            backend,
            SessionAlgo::KMeans,
            v0,
            &params,
            &PruneConfig::default(),
            SessionOptions::default(),
            None,
        )
        .unwrap();
        assert!(exact.result.converged && pruned.result.converged);
        // Margin-exact pruning: only f32 accumulation-order rounding (and
        // at most boundary-record flips it induces) separates the arms.
        let shift = max_center_shift2(&exact.result.centers, &pruned.result.centers);
        assert!(shift < 1e-4, "K-Means pruned arm drifted: {shift}");
    }

    #[test]
    fn session_loop_classic_variant_runs_pruned() {
        let (store, v0, params, backend) = session_setup(91);
        let params = FcmParams { variant: Variant::Classic, ..params };
        let mut engine = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let run = run_fcm_session(
            &mut engine,
            &store,
            backend,
            SessionAlgo::Fcm,
            v0,
            &params,
            &PruneConfig::default(),
            SessionOptions::default(),
            None,
        )
        .unwrap();
        assert!(run.result.converged);
        assert!(run.records_pruned > 0, "classic variant must prune too");
    }

    #[test]
    fn session_loop_rejects_bad_seeds() {
        let (store, _, params, backend) = session_setup(95);
        let mut engine = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let bad_dims = Matrix::zeros(3, 7);
        assert!(run_fcm_session(
            &mut engine,
            &store,
            Arc::clone(&backend),
            SessionAlgo::Fcm,
            bad_dims,
            &params,
            &PruneConfig::default(),
            SessionOptions::default(),
            None,
        )
        .is_err());
        let no_seeds = Matrix::zeros(0, 3);
        assert!(run_fcm_session(
            &mut engine,
            &store,
            backend,
            SessionAlgo::Fcm,
            no_seeds,
            &params,
            &PruneConfig::default(),
            SessionOptions::default(),
            None,
        )
        .is_err());
    }

    /// The bugfix regression: a mid-session `BlockCache::clear()` (the old
    /// between-jobs metering idiom) must never yield stale pruned partials
    /// — the sticky slab lives outside the block cache, so the interrupted
    /// run's arithmetic is bit-identical to the uninterrupted one.
    fn manual_pruned_run(clear_between: bool) -> (Matrix, u64, bool) {
        let data = blobs(1024, 3, 3, 0.25, 73);
        let store =
            Arc::new(BlockStore::in_memory("t", &data.features, 128, 4).unwrap());
        let mut rng = Pcg::new(74);
        let v0 = seeding::random_records(&data.features, 3, &mut rng);
        let params = FcmParams { epsilon: 1e-10, ..Default::default() };
        let prune = PruneConfig::default();
        let slab = Arc::new(StateSlab::with_budget_bytes(prune.slab_bytes));
        let job = Arc::new(SessionPartialsJob::new(
            SessionAlgo::Fcm.kernel(params.variant),
            params.m,
            Arc::new(NativeBackend),
            Arc::clone(&slab),
            prune,
        ));
        let mut engine = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let mut session = engine.session(&store, SessionOptions::default());
        let cache = Arc::new(DistributedCache::new());
        let mut v = v0;
        let mut pruned_total = 0u64;
        let mut converged = false;
        for _ in 0..params.max_iterations {
            cache.put_matrix(KEY_SESSION_CENTERS, v.clone());
            let (partials, _) = session
                .run_iteration(Arc::clone(&job), Arc::clone(&cache))
                .unwrap();
            let pruned_this = slab.take_records_pruned();
            pruned_total += pruned_this;
            let v_new = partials.into_centers(&v);
            let shift = max_center_shift2(&v, &v_new);
            v = v_new;
            if clear_between {
                // The hazardous idiom: dropping every warm block between
                // iterations. Must cost performance only, never staleness.
                session.engine().block_cache().clear();
            }
            if shift <= params.epsilon {
                if pruned_this > 0 {
                    slab.invalidate_all();
                    continue;
                }
                converged = true;
                break;
            }
        }
        (v, pruned_total, converged)
    }

    #[test]
    fn adaptive_refresh_extends_cap_on_smooth_convergence_and_stays_exact() {
        let (store, v0, params, backend) = session_setup(97);
        let mut exact_engine = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let exact = run_fcm_session(
            &mut exact_engine,
            &store,
            Arc::clone(&backend),
            SessionAlgo::Fcm,
            v0.clone(),
            &params,
            &PruneConfig::disabled(),
            SessionOptions::default(),
            None,
        )
        .unwrap();
        let prune = PruneConfig { adaptive_refresh: true, ..PruneConfig::default() };
        let mut engine = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let adaptive = run_fcm_session(
            &mut engine,
            &store,
            Arc::clone(&backend),
            SessionAlgo::Fcm,
            v0.clone(),
            &params,
            &prune,
            SessionOptions::default(),
            None,
        )
        .unwrap();
        assert!(adaptive.result.converged);
        let base = prune.refresh_every.max(1);
        let max_cap = adaptive.per_iteration.iter().map(|s| s.refresh_cap).max().unwrap();
        assert!(
            max_cap > base,
            "smoothly converging loop never extended the drift cap (max {max_cap}, base {base})"
        );
        assert!(
            adaptive.per_iteration.iter().all(|s| s.refresh_cap <= base * 8),
            "cap exceeded its 8× ceiling"
        );
        let shift = max_center_shift2(&exact.result.centers, &adaptive.result.centers);
        assert!(shift < 1e-3, "adaptive-cap run drifted from exact: {shift}");

        // The fixed-cap control: adaptivity off pins the cap to the base.
        let fixed = PruneConfig { adaptive_refresh: false, ..PruneConfig::default() };
        let mut fixed_engine = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let fixed_run = run_fcm_session(
            &mut fixed_engine,
            &store,
            backend,
            SessionAlgo::Fcm,
            v0,
            &params,
            &fixed,
            SessionOptions::default(),
            None,
        )
        .unwrap();
        assert!(fixed_run.per_iteration.iter().all(|s| s.refresh_cap == base));
    }

    /// The chaos layer's recovery contract: a run killed at iteration k
    /// and resumed from its checkpoint converges to bitwise the same
    /// centers as the uninterrupted run (pruning off — each iteration is a
    /// pure function of the incoming centers).
    #[test]
    fn kill_at_k_then_resume_converges_to_same_centers() {
        let (store, v0, params, backend) = session_setup(61);
        let mut e1 = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let full = run_fcm_session(
            &mut e1,
            &store,
            Arc::clone(&backend),
            SessionAlgo::Fcm,
            v0.clone(),
            &params,
            &PruneConfig::disabled(),
            SessionOptions::default(),
            None,
        )
        .unwrap();
        assert!(full.result.converged);
        assert!(full.result.iterations > 3, "control too short to kill at 3");
        assert_eq!(full.checkpoints_written, 0, "no policy, no checkpoints");

        // Kill at iteration 3 (max_iterations as the kill switch) with a
        // checkpoint after every iteration.
        let dir =
            std::env::temp_dir().join(format!("bigfcm_ckpt_loop_{}", std::process::id()));
        let policy = CheckpointPolicy { every: 1, path: dir.join("s.ckpt") };
        let killed_params = FcmParams { max_iterations: 3, ..params };
        let mut e2 = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let killed = run_fcm_session(
            &mut e2,
            &store,
            Arc::clone(&backend),
            SessionAlgo::Fcm,
            v0.clone(),
            &killed_params,
            &PruneConfig::disabled(),
            SessionOptions::default(),
            Some(&policy),
        )
        .unwrap();
        assert!(!killed.result.converged);
        assert_eq!(killed.checkpoints_written, 3);
        assert!(killed.checkpoint_bytes > 0);

        // Resume: the newest checkpoint's centers warm-start a fresh run.
        let cp = SessionCheckpoint::load(&policy.path).unwrap();
        assert_eq!(cp.iteration, 3);
        assert_eq!(cp.centers.as_slice(), killed.result.centers.as_slice());
        let mut e3 = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let resumed = run_fcm_session(
            &mut e3,
            &store,
            backend,
            SessionAlgo::Fcm,
            cp.centers.clone(),
            &params,
            &PruneConfig::disabled(),
            SessionOptions::default(),
            None,
        )
        .unwrap();
        assert!(resumed.result.converged);
        assert_eq!(
            resumed.result.centers.as_slice(),
            full.result.centers.as_slice(),
            "resume drifted from the uninterrupted run"
        );
        assert_eq!(
            cp.iteration as usize + resumed.result.iterations,
            full.result.iterations,
            "resume re-ran or skipped iterations"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sharded_session_exact_merge_is_bitwise_drop_in() {
        let (store, v0, params, backend) = session_setup(123);
        let mut single = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let base = run_fcm_session(
            &mut single,
            &store,
            Arc::clone(&backend),
            SessionAlgo::Fcm,
            v0.clone(),
            &params,
            &PruneConfig::default(),
            SessionOptions::default(),
            None,
        )
        .unwrap();
        assert!(base.result.converged);
        for shards in [1usize, 2, 3] {
            let mut sharded = ShardedEngine::new(
                &store,
                &EngineOptions::default(),
                OverheadConfig::default(),
                shards,
                4.0,
            );
            let r = run_fcm_session_sharded(
                &mut sharded,
                &store,
                Arc::clone(&backend),
                SessionAlgo::Fcm,
                v0.clone(),
                &params,
                &PruneConfig::default(),
                SessionOptions::default(),
                None,
                ShardMergeMode::Exact,
            )
            .unwrap();
            assert_eq!(
                r.run.result.centers.as_slice(),
                base.result.centers.as_slice(),
                "shards={shards}: exact merge must be a bitwise drop-in"
            );
            assert_eq!(r.run.result.iterations, base.result.iterations, "shards={shards}");
            assert_eq!(
                r.run.records_pruned, base.records_pruned,
                "shards={shards}: pruning decisions diverged"
            );
            assert_eq!(r.shards, shards);
            assert_eq!(r.merge_objective_delta_max, 0.0, "exact merge reports no delta");
            assert_eq!(r.records_pruned_per_shard.len(), shards);
            if shards > 1 {
                assert!(
                    r.records_pruned_per_shard.iter().all(|&p| p > 0),
                    "shards={shards}: every shard must prune ({:?})",
                    r.records_pruned_per_shard
                );
            }
            // Resident sharded loop: startup once per shard, no more.
            let startup = OverheadConfig::default().job_startup_s;
            let paid = r.run.sim.job_startup_s / startup;
            assert!(
                (paid - shards as f64).abs() < 1e-9,
                "shards={shards}: startup charged {paid} times"
            );
            assert_eq!(r.per_shard_last.len(), shards);
            assert_eq!(r.per_shard_peak_resident_bytes.len(), shards);
            assert!(r.per_shard_peak_resident_bytes.iter().all(|&b| b > 0));
        }
    }

    #[test]
    fn sharded_session_representative_merge_reports_delta_and_stays_close() {
        let (store, v0, _, backend) = session_setup(131);
        let params = FcmParams { epsilon: 1e-7, ..Default::default() };
        let mut single = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let exact = run_fcm_session(
            &mut single,
            &store,
            Arc::clone(&backend),
            SessionAlgo::Fcm,
            v0.clone(),
            &params,
            &PruneConfig::disabled(),
            SessionOptions::default(),
            None,
        )
        .unwrap();
        assert!(exact.result.converged);
        let mut sharded = ShardedEngine::new(
            &store,
            &EngineOptions::default(),
            OverheadConfig::default(),
            2,
            4.0,
        );
        let r = run_fcm_session_sharded(
            &mut sharded,
            &store,
            backend,
            SessionAlgo::Fcm,
            v0,
            &params,
            &PruneConfig::disabled(),
            SessionOptions::default(),
            None,
            ShardMergeMode::Representative,
        )
        .unwrap();
        assert!(r.run.result.converged, "representative arm did not converge");
        assert_eq!(r.merge, ShardMergeMode::Representative);
        assert!(r.merge_objective_delta_max.is_finite());
        assert!(r.merge_objective_delta_max >= r.merge_objective_delta);
        // Shards see i.i.d. slices of the same mixture, so the
        // centers-only exchange must land near the exact fixpoint
        // (EXPERIMENTS.md documents this tolerance).
        let shift = max_center_shift2(&exact.result.centers, &r.run.result.centers);
        assert!(shift < 1e-2, "representative merge drifted from exact: {shift}");
    }

    #[test]
    fn mid_session_cache_clear_never_stales_pruned_partials() {
        let (clean, clean_pruned, clean_conv) = manual_pruned_run(false);
        let (cleared, cleared_pruned, cleared_conv) = manual_pruned_run(true);
        assert!(clean_conv && cleared_conv);
        assert!(clean_pruned > 0, "the scenario must actually exercise pruning");
        assert_eq!(
            clean.as_slice(),
            cleared.as_slice(),
            "mid-session clear() changed pruned results — slab lifetime leaked into the cache"
        );
        assert_eq!(clean_pruned, cleared_pruned, "pruning decisions diverged");
    }
}
