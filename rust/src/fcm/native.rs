//! Pure-rust [`KernelBackend`] — the same math as the Pallas kernels
//! (`python/compile/kernels/fcm_pallas.py`), validated against the AOT
//! golden vectors in `rust/tests/integration_runtime.rs`.
//!
//! Used by: the driver job (tiny sample, not worth a PJRT round-trip),
//! unit tests, and as the `Backend::Native` ablation arm.
//!
//! This module owns only the **kernels**: exact partials per [`Kernel`]
//! (including the fused classic path that skips the O(C²) pair loop, and
//! the pair-loop variant kept as the Mahout compute model / property-test
//! oracle) plus the bound-emitting pass behind
//! [`KernelBackend::partials_with_bounds`]. The pruning protocol itself —
//! bound state, shift maintenance, replay/gather — lives once, backend-
//! portably, in [`crate::fcm::backend`].
//!
//! ## Kernel layout (EXPERIMENTS.md §Perf)
//!
//! The hot entry points (`fcm_partials_native`, `classic_partials_native`,
//! `kmeans_partials_native`) run a **tiled distance pass**: records are
//! processed in [`TILE_ROWS`]-row tiles against a transposed (d × C) center
//! panel, so the innermost loop walks one contiguous f32 slice of center
//! components per dimension — independent f32 lanes the autovectorizer maps
//! straight onto SIMD registers. Distances accumulate in f32 lanes
//! (squared-difference form — no ‖x‖²−2x·v+‖v‖² cancellation) and are
//! promoted to f64 at the tile boundary, where the membership reduction
//! runs exactly as the scalar reference. `powf` dominates the generic path,
//! so the paper's default m=2 (p = 1, u^m = x⁻²) takes a
//! transcendental-free fast path everywhere.
//!
//! The original scalar per-row loops are kept verbatim as
//! `*_partials_scalar` — the correctness reference the tiled path is
//! property-tested against (`rust/tests/prop_invariants.rs`) and the
//! baseline arm of the `micro_hotpath` A/B.

use crate::data::matrix::dist2;
use crate::data::Matrix;
use crate::error::Result;
use crate::fcm::backend::{BoundRows, Kernel, KernelBackend};
use crate::fcm::Partials;

/// Squared-distance clamp floor of every membership evaluation — shared
/// with the quant pre-pass, whose certified intervals must live in the
/// same clamped domain as the exact kernels' distances.
pub(crate) const DIST_EPS: f64 = 1e-12;

/// Default row-tile height of the tiled distance pass — the proven
/// mid-shape choice [`tile_rows_for`] falls back to. 8 rows × C f32 lanes
/// keeps the tile's distance block plus the center panel row in L1 across
/// the middle of the experiment matrix while giving the vectorizer long
/// independent lanes.
pub const TILE_ROWS: usize = 8;

/// Row-tile height for a (d, C) kernel shape (ROADMAP kernel follow-up:
/// autotune instead of the hardcoded 8).
///
/// The tile-resident working set is ≈ `tile × (C + d)` f32 — the tile's
/// distance block plus its row slab — sitting next to the (d × C) center
/// panel. The lookup sizes the tile so that set stays within about half a
/// 32 KiB L1 (2048 f32 slots) while giving the vectorizer the longest
/// lanes the shape affords: skinny shapes (small d·C) take tall tiles for
/// panel reuse, fat shapes shrink the tile to stay cache-resident. The
/// scalar oracles remain the property-test reference for every bucket, so
/// the lookup can only move speed, never results beyond f32-lane rounding.
pub fn tile_rows_for(d: usize, c: usize) -> usize {
    let per_row = (c + d).max(1);
    match 2048 / per_row {
        0..=7 => 4,
        8..=15 => TILE_ROWS,
        16..=31 => 16,
        _ => 32,
    }
}

/// The native backend is stateless.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend
    }
}

impl KernelBackend for NativeBackend {
    fn exact_partials(
        &self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
    ) -> Result<Partials> {
        Ok(match kernel {
            Kernel::FcmFast => fcm_partials_native(x, v, w, m),
            Kernel::FcmClassic => classic_partials_fused(x, v, w, m),
            Kernel::FcmClassicPair => classic_partials_native(x, v, w, m),
            Kernel::KMeans => kmeans_partials_native(x, v, w),
        })
    }

    fn partials_with_bounds(
        &self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
        rows: &mut BoundRows,
    ) -> Result<Partials> {
        Ok(partials_with_bounds_native(kernel, x, v, w, m, rows))
    }

    /// Direct tiled membership kernel — skips the generic default's
    /// partials accumulation and bound-row marshalling.
    fn score_chunk(
        &self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        m: f64,
        u: &mut Matrix,
    ) -> Result<()> {
        score_rows_native(kernel, x, v, m, u);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// f32-lane squared-distance pass over one row tile.
///
/// `rows` is a `t × d` row-major slab, `panel` the (d × C) transposed center
/// matrix; on return `out[r·C + i] = Σ_j (rows[r][j] − v[i][j])²`. Each
/// row's lane accumulates in the same j-order regardless of its position in
/// the tile, so per-record distances are bit-identical under any row split —
/// the combiner-associativity property the engine relies on.
fn tile_dist2(rows: &[f32], t: usize, d: usize, panel: &Matrix, out: &mut [f32]) {
    let c = panel.cols();
    debug_assert_eq!(panel.rows(), d);
    debug_assert_eq!(rows.len(), t * d);
    debug_assert_eq!(out.len(), t * c);
    for acc in out.iter_mut() {
        *acc = 0.0;
    }
    for j in 0..d {
        let pj = panel.row(j); // component j of every center, contiguous
        for r in 0..t {
            let xrj = rows[r * d + j];
            let lane = &mut out[r * c..(r + 1) * c];
            for (acc, &vj) in lane.iter_mut().zip(pj) {
                let diff = xrj - vj;
                *acc += diff * diff;
            }
        }
    }
}

/// Fast-FCM partials (Kolen–Hutcheson), tiled: computes u^m directly from
/// the distance vector of each record — O(C·d) per record, no membership
/// matrix. Distances come from the f32-lane tile pass; the membership
/// reduction is f64 per record, matching [`fcm_partials_scalar`] to f32
/// rounding (property-tested in `prop_invariants.rs`).
pub fn fcm_partials_native(x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Partials {
    let (c, d) = (v.rows(), v.cols());
    debug_assert_eq!(x.rows(), w.len());
    let mut out = Partials::zeros(c, d);
    if c == 0 {
        return out;
    }
    let p = 1.0 / (m - 1.0);
    let m2 = m == 2.0; // p = 1, (num·den)^-m = 1/(num·den)²
    let panel = v.transposed();
    let tile = tile_rows_for(d, c);
    // Scratch reused across tiles to keep the hot loop allocation-free.
    let mut d2t = vec![0.0f32; tile * c];
    let mut num = vec![0.0f64; c];
    let mut d2v = vec![0.0f64; c];
    for (base, t, rows) in x.iter_row_tiles(tile) {
        tile_dist2(rows, t, d, &panel, &mut d2t[..t * c]);
        for r in 0..t {
            let wk = w[base + r] as f64;
            if wk == 0.0 {
                continue; // padding contract
            }
            // f64 reduction at the tile boundary. Memberships depend only on
            // distance ratios; normalising by the row minimum before powering
            // avoids under/overflow at small m (matches the Pallas kernel,
            // fcm_pallas._um_fast).
            let lane = &d2t[r * c..(r + 1) * c];
            let mut dmin = f64::INFINITY;
            for i in 0..c {
                let d2 = (lane[i] as f64).max(DIST_EPS);
                d2v[i] = d2;
                dmin = dmin.min(d2);
            }
            let mut den = 0.0f64;
            if m2 {
                for i in 0..c {
                    let n = d2v[i] / dmin;
                    num[i] = n;
                    den += 1.0 / n;
                }
            } else {
                for i in 0..c {
                    let n = (d2v[i] / dmin).powf(p);
                    num[i] = n;
                    den += 1.0 / n;
                }
            }
            let row = &rows[r * d..(r + 1) * d];
            for i in 0..c {
                let um = if m2 {
                    let nd = num[i] * den;
                    wk / (nd * nd)
                } else {
                    (num[i] * den).powf(-m) * wk
                };
                out.w_acc[i] += um;
                out.objective += um * d2v[i];
                let umf = um as f32;
                let vrow = out.v_num.row_mut(i);
                for (val, &xj) in vrow.iter_mut().zip(row) {
                    *val += umf * xj;
                }
            }
        }
    }
    out
}

/// Classic-FCM partials, tiled: the explicit O(C²) ratio sum per record —
/// the "basic FCM" complexity the paper contrasts against (and the compute
/// model of the Mahout FKM baseline; the pair loop is kept so that model
/// stays honest). Powered distances are hoisted out of the pair loop:
/// `powf` cost is C per record instead of C².
pub fn classic_partials_native(x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Partials {
    let (c, d) = (v.rows(), v.cols());
    let mut out = Partials::zeros(c, d);
    if c == 0 {
        return out;
    }
    let p = 1.0 / (m - 1.0);
    let m2 = m == 2.0;
    let panel = v.transposed();
    let tile = tile_rows_for(d, c);
    let mut d2t = vec![0.0f32; tile * c];
    let mut d2v = vec![0.0f64; c];
    let mut dp = vec![0.0f64; c];
    for (base, t, rows) in x.iter_row_tiles(tile) {
        tile_dist2(rows, t, d, &panel, &mut d2t[..t * c]);
        for r in 0..t {
            let wk = w[base + r] as f64;
            if wk == 0.0 {
                continue;
            }
            let lane = &d2t[r * c..(r + 1) * c];
            let mut dmin = f64::INFINITY;
            for i in 0..c {
                let d2 = (lane[i] as f64).max(DIST_EPS);
                d2v[i] = d2;
                dmin = dmin.min(d2);
            }
            // powf hoist: dp[i] = (d_i/dmin)^p once per (record, cluster);
            // the dmin normalisation keeps dp ≥ ~1 so ratios cannot
            // overflow, and it cancels in dp[i]/dp[j] below.
            if m2 {
                for i in 0..c {
                    dp[i] = d2v[i] / dmin;
                }
            } else {
                for i in 0..c {
                    dp[i] = (d2v[i] / dmin).powf(p);
                }
            }
            let row = &rows[r * d..(r + 1) * d];
            for i in 0..c {
                // u_i = 1 / Σ_j (d_i/d_j)^p — the textbook double loop,
                // over precomputed powers.
                let mut s = 0.0f64;
                for j in 0..c {
                    s += dp[i] / dp[j];
                }
                let u = 1.0 / s;
                let um = if m2 { u * u * wk } else { u.powf(m) * wk };
                out.w_acc[i] += um;
                out.objective += um * d2v[i];
                let vrow = out.v_num.row_mut(i);
                for (jj, val) in vrow.iter_mut().enumerate() {
                    *val += (um * row[jj] as f64) as f32;
                }
            }
        }
    }
    out
}

/// Classic-FCM partials with the pair loop **fused away** (ROADMAP kernel
/// follow-up): the textbook membership `u_i = 1 / Σ_j (d_i/d_j)^p` is
/// computed as one reciprocal sum per record — `u_i = nrm_i⁻¹ / Σ_j
/// nrm_j⁻¹` over the dmin-normalised powered distances — so the per-record
/// cost drops from O(C²) to O(C) while following the classic formulation
/// (u first, then uᵐ). Algebraically identical to the pair loop, which is
/// kept in [`classic_partials_native`] as the Mahout-FKM compute model and
/// the property-test oracle of this path
/// (`prop_fused_classic_matches_pair_oracle`).
pub fn classic_partials_fused(x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Partials {
    let (c, d) = (v.rows(), v.cols());
    let mut out = Partials::zeros(c, d);
    if c == 0 {
        return out;
    }
    let p = 1.0 / (m - 1.0);
    let m2 = m == 2.0;
    let panel = v.transposed();
    let tile = tile_rows_for(d, c);
    let mut d2t = vec![0.0f32; tile * c];
    let mut d2v = vec![0.0f64; c];
    let mut inv = vec![0.0f64; c];
    for (base, t, rows) in x.iter_row_tiles(tile) {
        tile_dist2(rows, t, d, &panel, &mut d2t[..t * c]);
        for r in 0..t {
            let wk = w[base + r] as f64;
            if wk == 0.0 {
                continue; // padding contract
            }
            let lane = &d2t[r * c..(r + 1) * c];
            let mut dmin = f64::INFINITY;
            for i in 0..c {
                let d2 = (lane[i] as f64).max(DIST_EPS);
                d2v[i] = d2;
                dmin = dmin.min(d2);
            }
            // inv[i] = (d_i/dmin)^-p; the dmin normalisation cancels in the
            // ratio u_i = inv[i] / Σ_j inv[j] and keeps every term ≤ 1.
            let mut s = 0.0f64;
            if m2 {
                for i in 0..c {
                    let ri = dmin / d2v[i];
                    inv[i] = ri;
                    s += ri;
                }
            } else {
                for i in 0..c {
                    let ri = (dmin / d2v[i]).powf(p);
                    inv[i] = ri;
                    s += ri;
                }
            }
            let row = &rows[r * d..(r + 1) * d];
            for i in 0..c {
                let u = inv[i] / s;
                let um = if m2 { u * u * wk } else { u.powf(m) * wk };
                out.w_acc[i] += um;
                out.objective += um * d2v[i];
                let umf = um as f32;
                let vrow = out.v_num.row_mut(i);
                for (val, &xj) in vrow.iter_mut().zip(row) {
                    *val += umf * xj;
                }
            }
        }
    }
    out
}

/// Hard K-Means partials, tiled: per-cluster weighted sums/counts + SSE.
pub fn kmeans_partials_native(x: &Matrix, v: &Matrix, w: &[f32]) -> Partials {
    let (c, d) = (v.rows(), v.cols());
    let mut out = Partials::zeros(c, d);
    if c == 0 {
        return out;
    }
    let panel = v.transposed();
    let tile = tile_rows_for(d, c);
    let mut d2t = vec![0.0f32; tile * c];
    for (base, t, rows) in x.iter_row_tiles(tile) {
        tile_dist2(rows, t, d, &panel, &mut d2t[..t * c]);
        for r in 0..t {
            let wk = w[base + r] as f64;
            if wk == 0.0 {
                continue;
            }
            let lane = &d2t[r * c..(r + 1) * c];
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for (i, &d2) in lane.iter().enumerate() {
                let dd = (d2 as f64).max(DIST_EPS);
                if dd < best_d {
                    best_d = dd;
                    best = i;
                }
            }
            out.w_acc[best] += wk;
            out.objective += wk * best_d;
            let row = &rows[r * d..(r + 1) * d];
            let vrow = out.v_num.row_mut(best);
            for (j, val) in vrow.iter_mut().enumerate() {
                *val += (wk * row[j] as f64) as f32;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Bound-emitting exact pass (the backend primitive behind the portable
// pruning protocol of `crate::fcm::backend`)
// ---------------------------------------------------------------------------

/// Private FCM membership flavor of the bound-emitting pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum FcmFlavor {
    /// Kolen–Hutcheson normalised form (the Fast kernel's math).
    Fast,
    /// Fused classic: u first via one reciprocal sum, then u^m.
    ClassicFused,
    /// Textbook O(C²) ratio sum over hoisted powers (the pair loop).
    ClassicPair,
}

/// Per-record u^m·w weights, matching the respective exact kernels' math
/// (and their m = 2 fast paths).
#[allow(clippy::too_many_arguments)]
fn compute_um(
    flavor: FcmFlavor,
    p_exp: f64,
    m: f64,
    m2: bool,
    d2v: &[f64],
    dmin: f64,
    wk: f64,
    um: &mut [f64],
    scratch: &mut [f64],
) {
    let c = d2v.len();
    match flavor {
        FcmFlavor::Fast => {
            let mut den = 0.0f64;
            for i in 0..c {
                let nrm = if m2 { d2v[i] / dmin } else { (d2v[i] / dmin).powf(p_exp) };
                scratch[i] = nrm;
                den += 1.0 / nrm;
            }
            for i in 0..c {
                um[i] = if m2 {
                    let nd = scratch[i] * den;
                    wk / (nd * nd)
                } else {
                    (scratch[i] * den).powf(-m) * wk
                };
            }
        }
        FcmFlavor::ClassicFused => {
            let mut s = 0.0f64;
            for i in 0..c {
                let inv = if m2 { dmin / d2v[i] } else { (dmin / d2v[i]).powf(p_exp) };
                scratch[i] = inv;
                s += inv;
            }
            for i in 0..c {
                let u = scratch[i] / s;
                um[i] = if m2 { u * u * wk } else { u.powf(m) * wk };
            }
        }
        FcmFlavor::ClassicPair => {
            for i in 0..c {
                scratch[i] = if m2 { d2v[i] / dmin } else { (d2v[i] / dmin).powf(p_exp) };
            }
            for i in 0..c {
                let mut s = 0.0f64;
                for j in 0..c {
                    s += scratch[i] / scratch[j];
                }
                let u = 1.0 / s;
                um[i] = if m2 { u * u * wk } else { u.powf(m) * wk };
            }
        }
    }
}

/// Exact tiled pass that also fills [`BoundRows`] — clamped per-center
/// squared distances, per-record contributions/assignments and objective
/// terms — for every row, in row order. Zero-weight rows contribute
/// nothing to the partials (their bound rows hold distances but zeroed
/// contributions), honouring the padding contract.
pub fn partials_with_bounds_native(
    kernel: Kernel,
    x: &Matrix,
    v: &Matrix,
    w: &[f32],
    m: f64,
    rows: &mut BoundRows,
) -> Partials {
    let (n, c, d) = (x.rows(), v.rows(), v.cols());
    debug_assert_eq!(n, w.len());
    debug_assert_eq!(rows.d2.rows(), n);
    debug_assert_eq!(rows.d2.cols(), c);
    debug_assert_eq!(rows.obj.len(), n);
    let mut out = Partials::zeros(c, d);
    if c == 0 {
        return out;
    }
    let kmeans = kernel.is_kmeans();
    let flavor = match kernel {
        Kernel::FcmFast => FcmFlavor::Fast,
        Kernel::FcmClassic => FcmFlavor::ClassicFused,
        Kernel::FcmClassicPair => FcmFlavor::ClassicPair,
        Kernel::KMeans => FcmFlavor::Fast, // unused on the K-Means path
    };
    let p_exp = if kmeans { 0.0 } else { 1.0 / (m - 1.0) };
    let m2 = m == 2.0;
    let panel = v.transposed();
    let tile = tile_rows_for(d, c);
    let mut d2t = vec![0.0f32; tile * c];
    let mut d2v = vec![0.0f64; c];
    let mut um_buf = vec![0.0f64; c];
    let mut scratch = vec![0.0f64; c];
    for (base, t, slab) in x.iter_row_tiles(tile) {
        tile_dist2(slab, t, d, &panel, &mut d2t[..t * c]);
        for r in 0..t {
            let k = base + r;
            let wk = w[k] as f64;
            let lane = &d2t[r * c..(r + 1) * c];
            let row = &slab[r * d..(r + 1) * d];
            let d2row = rows.d2.row_mut(k);
            if kmeans {
                let mut best = 0usize;
                let mut best_d = f64::INFINITY;
                for (i, &dl) in lane.iter().enumerate() {
                    let dd = (dl as f64).max(DIST_EPS);
                    d2row[i] = dd as f32;
                    if dd < best_d {
                        best_d = dd;
                        best = i;
                    }
                }
                rows.best[k] = best as u32;
                if wk == 0.0 {
                    rows.obj[k] = 0.0;
                    continue;
                }
                out.w_acc[best] += wk;
                let obj_k = wk * best_d;
                out.objective += obj_k;
                rows.obj[k] = obj_k as f32;
                let vrow = out.v_num.row_mut(best);
                for (j, val) in vrow.iter_mut().enumerate() {
                    *val += (wk * row[j] as f64) as f32;
                }
            } else {
                let mut dmin = f64::INFINITY;
                for (i, &dl) in lane.iter().enumerate() {
                    let dd = (dl as f64).max(DIST_EPS);
                    d2v[i] = dd;
                    d2row[i] = dd as f32;
                    dmin = dmin.min(dd);
                }
                let um_row = rows.um.row_mut(k);
                if wk == 0.0 {
                    rows.obj[k] = 0.0;
                    um_row.fill(0.0);
                    continue;
                }
                compute_um(flavor, p_exp, m, m2, &d2v, dmin, wk, &mut um_buf, &mut scratch);
                let mut obj_k = 0.0f64;
                for i in 0..c {
                    let u = um_buf[i];
                    out.w_acc[i] += u;
                    obj_k += u * d2v[i];
                    let uf = u as f32;
                    um_row[i] = uf;
                    let vrow = out.v_num.row_mut(i);
                    for (val, &xj) in vrow.iter_mut().zip(row) {
                        *val += uf * xj;
                    }
                }
                out.objective += obj_k;
                rows.obj[k] = obj_k as f32;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Scalar reference kernels
// ---------------------------------------------------------------------------

/// Scalar fast-FCM reference: per-row f64 distances, no tiling. This is the
/// pre-optimization hot path, kept verbatim as the oracle the tiled kernel
/// is property-tested against and as the `micro_hotpath` A/B baseline.
pub fn fcm_partials_scalar(x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Partials {
    let (c, d) = (v.rows(), v.cols());
    debug_assert_eq!(x.rows(), w.len());
    let mut out = Partials::zeros(c, d);
    let p = 1.0 / (m - 1.0);
    let m2 = m == 2.0;
    let mut num = vec![0.0f64; c];
    let mut d2v = vec![0.0f64; c];
    for (k, row) in x.iter_rows().enumerate() {
        let wk = w[k] as f64;
        if wk == 0.0 {
            continue; // padding contract
        }
        let mut dmin = f64::INFINITY;
        for i in 0..c {
            let d2 = dist2(row, v.row(i)).max(DIST_EPS);
            d2v[i] = d2;
            dmin = dmin.min(d2);
        }
        let mut den = 0.0f64;
        if m2 {
            for i in 0..c {
                let n = d2v[i] / dmin;
                num[i] = n;
                den += 1.0 / n;
            }
        } else {
            for i in 0..c {
                let n = (d2v[i] / dmin).powf(p);
                num[i] = n;
                den += 1.0 / n;
            }
        }
        for i in 0..c {
            let um = if m2 {
                let nd = num[i] * den;
                wk / (nd * nd)
            } else {
                (num[i] * den).powf(-m) * wk
            };
            out.w_acc[i] += um;
            out.objective += um * d2v[i];
            let umf = um as f32;
            let vrow = out.v_num.row_mut(i);
            for (val, &xj) in vrow.iter_mut().zip(row) {
                *val += umf * xj;
            }
        }
    }
    out
}

/// Scalar classic-FCM reference: the textbook O(C²) double loop with a
/// `powf` per (i, j) pair — exactly the pre-hoist formulation.
pub fn classic_partials_scalar(x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Partials {
    let (c, d) = (v.rows(), v.cols());
    let mut out = Partials::zeros(c, d);
    let p = 1.0 / (m - 1.0);
    let mut d2v = vec![0.0f64; c];
    for (k, row) in x.iter_rows().enumerate() {
        let wk = w[k] as f64;
        if wk == 0.0 {
            continue;
        }
        for i in 0..c {
            d2v[i] = dist2(row, v.row(i)).max(DIST_EPS);
        }
        for i in 0..c {
            // u_i = 1 / Σ_j (d_i/d_j)^p — the textbook double loop.
            let mut s = 0.0f64;
            for j in 0..c {
                s += (d2v[i] / d2v[j]).powf(p);
            }
            let u = 1.0 / s;
            let um = u.powf(m) * wk;
            out.w_acc[i] += um;
            out.objective += um * d2v[i];
            let vrow = out.v_num.row_mut(i);
            for (jj, val) in vrow.iter_mut().enumerate() {
                *val += (um * row[jj] as f64) as f32;
            }
        }
    }
    out
}

/// Scalar hard K-Means reference.
pub fn kmeans_partials_scalar(x: &Matrix, v: &Matrix, w: &[f32]) -> Partials {
    let (c, d) = (v.rows(), v.cols());
    let mut out = Partials::zeros(c, d);
    for (k, row) in x.iter_rows().enumerate() {
        let wk = w[k] as f64;
        if wk == 0.0 {
            continue;
        }
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for i in 0..c {
            let dd = dist2(row, v.row(i)).max(DIST_EPS);
            if dd < best_d {
                best_d = dd;
                best = i;
            }
        }
        out.w_acc[best] += wk;
        out.objective += wk * best_d;
        let vrow = out.v_num.row_mut(best);
        for (j, val) in vrow.iter_mut().enumerate() {
            *val += (wk * row[j] as f64) as f32;
        }
    }
    out
}

/// Tiled membership rows — the native [`KernelBackend::score_chunk`]
/// override (the serving hot path of `crate::serve`): the f32-lane tile
/// distance pass feeding one membership normalisation per record, no
/// partials accumulation, no weights. Every FCM kernel yields the textbook
/// distribution (the fused `u_i = (dmin/d_i)^p / Σ_j (dmin/d_j)^p` form,
/// m = 2 transcendental-free); K-Means rows are the one-hot assignment.
pub fn score_rows_native(kernel: Kernel, x: &Matrix, v: &Matrix, m: f64, u: &mut Matrix) {
    let (n, c, d) = (x.rows(), v.rows(), v.cols());
    debug_assert_eq!(u.rows(), n);
    debug_assert_eq!(u.cols(), c);
    if n == 0 || c == 0 {
        return;
    }
    let kmeans = kernel.is_kmeans();
    let p = if kmeans { 0.0 } else { 1.0 / (m - 1.0) };
    let m2 = m == 2.0;
    let panel = v.transposed();
    let tile = tile_rows_for(d, c);
    let mut d2t = vec![0.0f32; tile * c];
    let mut inv = vec![0.0f64; c];
    let mut d2v = vec![0.0f64; c];
    for (base, t, rows) in x.iter_row_tiles(tile) {
        tile_dist2(rows, t, d, &panel, &mut d2t[..t * c]);
        for r in 0..t {
            let lane = &d2t[r * c..(r + 1) * c];
            let urow = u.row_mut(base + r);
            if kmeans {
                let mut best = 0usize;
                let mut best_d = f64::INFINITY;
                for (i, &d2) in lane.iter().enumerate() {
                    let dd = (d2 as f64).max(DIST_EPS);
                    if dd < best_d {
                        best_d = dd;
                        best = i;
                    }
                }
                urow.fill(0.0);
                urow[best] = 1.0;
                continue;
            }
            for (dv, &d2) in d2v.iter_mut().zip(lane) {
                *dv = (d2 as f64).max(DIST_EPS);
            }
            // The one shared copy of the fused membership formula.
            crate::fcm::backend::membership_row_from_d2(&d2v, p, m2, &mut inv, urow);
        }
    }
}

/// Full membership matrix (N, C) — used by quality metrics, not the hot
/// path. Still worth the m=2 fast path: silhouette/confusion passes over
/// large N would otherwise pay a `powf` per (record, cluster).
pub fn memberships(x: &Matrix, v: &Matrix, m: f64) -> Matrix {
    let (n, c) = (x.rows(), v.rows());
    let p = 1.0 / (m - 1.0);
    let m2 = m == 2.0; // p = 1: ratios need no powering
    let mut u = Matrix::zeros(n, c);
    let mut num = vec![0.0f64; c];
    let mut d2v = vec![0.0f64; c];
    for k in 0..n {
        let row = x.row(k);
        let mut dmin = f64::INFINITY;
        for i in 0..c {
            let d2 = dist2(row, v.row(i)).max(DIST_EPS);
            d2v[i] = d2;
            dmin = dmin.min(d2);
        }
        let mut den = 0.0f64;
        if m2 {
            for i in 0..c {
                let nm = d2v[i] / dmin;
                num[i] = nm;
                den += 1.0 / nm;
            }
        } else {
            for i in 0..c {
                let nm = (d2v[i] / dmin).powf(p);
                num[i] = nm;
                den += 1.0 / nm;
            }
        }
        for i in 0..c {
            u.set(k, i, (1.0 / (num[i] * den)) as f32);
        }
    }
    u
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg;

    fn rand_case(n: usize, d: usize, c: usize, seed: u64) -> (Matrix, Matrix, Vec<f32>) {
        let mut rng = Pcg::new(seed);
        let mut x = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                x.set(i, j, rng.normal() as f32);
            }
        }
        let mut v = Matrix::zeros(c, d);
        for i in 0..c {
            for j in 0..d {
                v.set(i, j, rng.normal() as f32);
            }
        }
        let w = (0..n).map(|_| rng.next_f32() + 0.1).collect();
        (x, v, w)
    }

    #[test]
    fn fast_equals_classic_partials() {
        // The Kolen–Hutcheson trick is algebraically identical to classic.
        let (x, v, w) = rand_case(200, 5, 4, 1);
        for m in [1.2, 2.0, 2.8] {
            let a = fcm_partials_native(&x, &v, &w, m);
            let b = classic_partials_native(&x, &v, &w, m);
            for (p, q) in a.v_num.as_slice().iter().zip(b.v_num.as_slice()) {
                assert!((p - q).abs() < 1e-3, "{p} vs {q} at m={m}");
            }
            for (p, q) in a.w_acc.iter().zip(&b.w_acc) {
                assert!((p - q).abs() < 1e-6);
            }
            assert!((a.objective - b.objective).abs() / b.objective.max(1e-9) < 1e-6);
        }
    }

    #[test]
    fn tiled_matches_scalar_reference() {
        // Awkward shapes: tail tiles (n % TILE_ROWS ≠ 0), d=1, C=1.
        for (n, d, c, seed) in [(67, 5, 4, 11), (8, 1, 3, 12), (13, 7, 1, 13), (256, 18, 6, 14)] {
            let (x, v, w) = rand_case(n, d, c, seed);
            for m in [1.2, 2.0, 2.8] {
                let a = fcm_partials_native(&x, &v, &w, m);
                let b = fcm_partials_scalar(&x, &v, &w, m);
                for (p, q) in a.v_num.as_slice().iter().zip(b.v_num.as_slice()) {
                    assert!((p - q).abs() <= 1e-3 + 1e-4 * q.abs(), "{p} vs {q} m={m} n={n}");
                }
                for (p, q) in a.w_acc.iter().zip(&b.w_acc) {
                    assert!((p - q).abs() <= 1e-6 + 1e-4 * q.abs(), "{p} vs {q} m={m} n={n}");
                }
                let rel = (a.objective - b.objective).abs() / b.objective.max(1e-9);
                assert!(rel < 1e-4, "objective {rel} m={m} n={n}");
            }
        }
    }

    #[test]
    fn classic_hoist_matches_scalar_reference() {
        let (x, v, w) = rand_case(100, 4, 5, 21);
        for m in [1.2, 2.0, 2.8] {
            let a = classic_partials_native(&x, &v, &w, m);
            let b = classic_partials_scalar(&x, &v, &w, m);
            for (p, q) in a.w_acc.iter().zip(&b.w_acc) {
                assert!((p - q).abs() <= 1e-6 + 1e-4 * q.abs(), "{p} vs {q} at m={m}");
            }
            let rel = (a.objective - b.objective).abs() / b.objective.max(1e-9);
            assert!(rel < 1e-4, "objective diverged: {rel} at m={m}");
        }
    }

    #[test]
    fn memberships_rows_sum_to_one() {
        let (x, v, _) = rand_case(100, 4, 3, 2);
        for m in [1.5, 2.0, 3.0] {
            let u = memberships(&x, &v, m);
            for i in 0..u.rows() {
                let s: f32 = u.row(i).iter().sum();
                assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s} at m={m}");
            }
        }
    }

    #[test]
    fn memberships_fast_path_matches_generic_at_m2() {
        // The m=2 shortcut must be the identical distribution, only cheaper.
        // 2.0 + tiny epsilon forces the generic powf arm for comparison.
        let (x, v, _) = rand_case(80, 3, 4, 6);
        let fast = memberships(&x, &v, 2.0);
        let generic = memberships(&x, &v, 2.0 + 1e-12);
        for (a, b) in fast.as_slice().iter().zip(generic.as_slice()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_weight_records_ignored() {
        let (x, v, mut w) = rand_case(64, 3, 2, 3);
        for wk in w.iter_mut().skip(32) {
            *wk = 0.0;
        }
        let full = fcm_partials_native(&x, &v, &w, 2.0);
        // Corrupt ignored rows; result must be identical.
        let mut x2 = x.clone();
        for i in 32..64 {
            for j in 0..3 {
                x2.set(i, j, 1e9);
            }
        }
        let same = fcm_partials_native(&x2, &v, &w, 2.0);
        assert_eq!(full.v_num.as_slice(), same.v_num.as_slice());
        assert_eq!(full.w_acc, same.w_acc);
    }

    #[test]
    fn partials_associativity() {
        let (x, v, w) = rand_case(128, 4, 3, 4);
        let full = fcm_partials_native(&x, &v, &w, 2.0);
        let mut merged = fcm_partials_native(&x.slice_rows(0, 64), &v, &w[..64], 2.0);
        let right = fcm_partials_native(&x.slice_rows(64, 128), &v, &w[64..], 2.0);
        merged.merge(&right);
        for (a, b) in merged.v_num.as_slice().iter().zip(full.v_num.as_slice()) {
            assert!((a - b).abs() < 1e-3);
        }
        for (a, b) in merged.w_acc.iter().zip(&full.w_acc) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn partials_associativity_unaligned_split() {
        // Split off the tile grid: per-record tile_dist2 lanes must not
        // depend on a row's position within its tile.
        let (x, v, w) = rand_case(61, 5, 4, 9);
        let full = fcm_partials_native(&x, &v, &w, 2.0);
        let mut merged = fcm_partials_native(&x.slice_rows(0, 29), &v, &w[..29], 2.0);
        merged.merge(&fcm_partials_native(&x.slice_rows(29, 61), &v, &w[29..], 2.0));
        for (a, b) in merged.v_num.as_slice().iter().zip(full.v_num.as_slice()) {
            assert!((a - b).abs() < 1e-3);
        }
        for (a, b) in merged.w_acc.iter().zip(&full.w_acc) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn kmeans_counts_sum_to_weight_mass() {
        let (x, v, w) = rand_case(256, 6, 5, 5);
        let p = kmeans_partials_native(&x, &v, &w);
        let total_w: f64 = w.iter().map(|&x| x as f64).sum();
        let total_c: f64 = p.w_acc.iter().sum();
        assert!((total_w - total_c).abs() < 1e-6);
    }

    #[test]
    fn kmeans_tiled_matches_scalar_on_separated_data() {
        // Hand-built well-separated clusters: the argmin margin dwarfs f32
        // rounding (a tiled/scalar flip would need a record equidistant to
        // two centers within f32 eps), so per-cluster sums must agree.
        let (c, d, n) = (4usize, 4usize, 500usize);
        let mut rng = Pcg::new(31);
        let mut v = Matrix::zeros(c, d);
        for i in 0..c {
            v.set(i, i % d, 8.0 * (i as f32 + 1.0));
        }
        let mut x = Matrix::zeros(n, d);
        for k in 0..n {
            let home = k % c;
            for j in 0..d {
                x.set(k, j, v.get(home, j) + (rng.normal() * 0.3) as f32);
            }
        }
        let w: Vec<f32> = (0..n).map(|i| 0.5 + (i % 5) as f32 * 0.3).collect();
        let a = kmeans_partials_native(&x, &v, &w);
        let b = kmeans_partials_scalar(&x, &v, &w);
        for (p, q) in a.w_acc.iter().zip(&b.w_acc) {
            assert!((p - q).abs() < 1e-9, "{p} vs {q}");
        }
        for (p, q) in a.v_num.as_slice().iter().zip(b.v_num.as_slice()) {
            assert!((p - q).abs() <= 1e-3 + 1e-4 * q.abs(), "{p} vs {q}");
        }
        let rel = (a.objective - b.objective).abs() / b.objective.max(1e-9);
        assert!(rel < 1e-4, "objective diverged: {rel}");
    }

    #[test]
    fn point_on_center_is_finite() {
        let x = Matrix::from_rows(&[vec![1.0, 1.0], vec![3.0, 3.0]]);
        let v = Matrix::from_rows(&[vec![1.0, 1.0], vec![5.0, 5.0]]);
        let p = fcm_partials_native(&x, &v, &[1.0, 1.0], 2.0);
        assert!(p.v_num.as_slice().iter().all(|v| v.is_finite()));
        assert!(p.w_acc.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn tile_autotune_buckets_are_sane() {
        // Skinny shapes take tall tiles, fat shapes shrink — and the
        // default lives in the middle.
        assert_eq!(tile_rows_for(1, 1), 32);
        assert_eq!(tile_rows_for(18, 6), 32); // SUSY shape
        assert_eq!(tile_rows_for(41, 50), 16); // widest experiment shape
        assert_eq!(tile_rows_for(120, 40), TILE_ROWS);
        assert_eq!(tile_rows_for(260, 40), 4);
        for (d, c) in [(1, 1), (3, 7), (41, 50), (120, 40), (260, 40)] {
            let t = tile_rows_for(d, c);
            assert!([4, 8, 16, 32].contains(&t), "odd tile {t} for ({d}, {c})");
        }
    }

    #[test]
    fn kernels_match_oracle_across_tile_buckets() {
        // One shape per autotune bucket: the tiled result must agree with
        // the scalar oracle whatever tile the lookup picks.
        for (d, c) in [(2, 3), (41, 50), (150, 30), (280, 20)] {
            let (x, v, w) = rand_case(73, d, c, 99);
            let a = fcm_partials_native(&x, &v, &w, 2.0);
            let b = fcm_partials_scalar(&x, &v, &w, 2.0);
            for (p, q) in a.w_acc.iter().zip(&b.w_acc) {
                assert!((p - q).abs() <= 1e-6 + 1e-4 * q.abs(), "({d},{c}): {p} vs {q}");
            }
            let rel = (a.objective - b.objective).abs() / b.objective.max(1e-9);
            assert!(rel < 1e-4, "({d},{c}): objective rel {rel}");
        }
    }

    #[test]
    fn fused_classic_matches_pair_loop() {
        // The fused O(C) path is algebraically the textbook membership;
        // the pair loop stays as its oracle.
        let (x, v, w) = rand_case(150, 5, 4, 51);
        for m in [1.2, 2.0, 2.8] {
            let a = classic_partials_fused(&x, &v, &w, m);
            let b = classic_partials_native(&x, &v, &w, m);
            for (p, q) in a.w_acc.iter().zip(&b.w_acc) {
                assert!((p - q).abs() <= 1e-6 + 1e-4 * q.abs(), "m={m}: {p} vs {q}");
            }
            for (p, q) in a.v_num.as_slice().iter().zip(b.v_num.as_slice()) {
                assert!((p - q).abs() <= 1e-3 + 1e-4 * q.abs(), "m={m}: vnum {p} vs {q}");
            }
            let rel = (a.objective - b.objective).abs() / b.objective.max(1e-9);
            assert!(rel < 1e-4, "m={m}: objective rel {rel}");
        }
    }

    #[test]
    fn bounds_pass_partials_match_exact_kernels() {
        use crate::fcm::backend::BoundRows;
        let (x, v, w) = rand_case(97, 4, 5, 52);
        for (kernel, m) in [
            (Kernel::FcmFast, 2.0),
            (Kernel::FcmFast, 1.7),
            (Kernel::FcmClassic, 2.0),
            (Kernel::FcmClassicPair, 2.3),
            (Kernel::KMeans, 0.0),
        ] {
            let mut rows = BoundRows::for_kernel(kernel, x.rows(), v.rows());
            let a = partials_with_bounds_native(kernel, &x, &v, &w, m, &mut rows);
            let b = NativeBackend.exact_partials(kernel, &x, &v, &w, m).unwrap();
            for (p, q) in a.w_acc.iter().zip(&b.w_acc) {
                assert!((p - q).abs() <= 1e-9 + 1e-7 * q.abs(), "{kernel:?}: {p} vs {q}");
            }
            let rel = (a.objective - b.objective).abs() / b.objective.max(1e-9);
            assert!(rel < 1e-6, "{kernel:?}: objective rel {rel}");
            // Every bound row carries the clamped distances the kernel used.
            for k in 0..x.rows() {
                for (i, &d2) in rows.d2.row(k).iter().enumerate() {
                    assert!(d2 > 0.0, "{kernel:?}: unclamped d2 at ({k},{i})");
                }
            }
        }
    }

    #[test]
    fn score_rows_match_memberships_oracle() {
        // The tiled scoring kernel is the serving path; the scalar
        // memberships() is its oracle (identical distribution, different
        // evaluation order).
        let (x, v, _) = rand_case(120, 5, 4, 61);
        for m in [1.4, 2.0, 2.7] {
            for kernel in [Kernel::FcmFast, Kernel::FcmClassic, Kernel::FcmClassicPair] {
                let mut u = Matrix::zeros(120, 4);
                score_rows_native(kernel, &x, &v, m, &mut u);
                let oracle = memberships(&x, &v, m);
                for (a, b) in u.as_slice().iter().zip(oracle.as_slice()) {
                    assert!((a - b).abs() < 1e-6, "{kernel:?} m={m}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn default_score_chunk_matches_native_override() {
        // A backend that only implements the two primitives gets scoring
        // through the provided default — it must agree with the native
        // direct kernel to f32 rounding.
        struct DefaultScore;
        impl KernelBackend for DefaultScore {
            fn exact_partials(
                &self,
                kernel: Kernel,
                x: &Matrix,
                v: &Matrix,
                w: &[f32],
                m: f64,
            ) -> Result<crate::fcm::Partials> {
                NativeBackend.exact_partials(kernel, x, v, w, m)
            }

            fn partials_with_bounds(
                &self,
                kernel: Kernel,
                x: &Matrix,
                v: &Matrix,
                w: &[f32],
                m: f64,
                rows: &mut BoundRows,
            ) -> Result<crate::fcm::Partials> {
                NativeBackend.partials_with_bounds(kernel, x, v, w, m, rows)
            }

            fn name(&self) -> &'static str {
                "default-score"
            }
        }
        let (x, v, _) = rand_case(90, 4, 3, 62);
        for (kernel, m) in
            [(Kernel::FcmFast, 2.0), (Kernel::FcmClassic, 1.7), (Kernel::KMeans, 0.0)]
        {
            let mut direct = Matrix::zeros(90, 3);
            NativeBackend.score_chunk(kernel, &x, &v, m, &mut direct).unwrap();
            let mut derived = Matrix::zeros(90, 3);
            DefaultScore.score_chunk(kernel, &x, &v, m, &mut derived).unwrap();
            for (a, b) in direct.as_slice().iter().zip(derived.as_slice()) {
                assert!((a - b).abs() < 1e-6, "{kernel:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn bounds_pass_zero_weight_rows_contribute_nothing() {
        use crate::fcm::backend::BoundRows;
        let (x, v, mut w) = rand_case(64, 3, 3, 53);
        for wk in w.iter_mut().skip(40) {
            *wk = 0.0;
        }
        let mut rows = BoundRows::for_kernel(Kernel::FcmFast, 64, 3);
        let a = partials_with_bounds_native(Kernel::FcmFast, &x, &v, &w, 2.0, &mut rows);
        let b = fcm_partials_native(&x, &v, &w, 2.0);
        assert_eq!(a.w_acc, b.w_acc);
        for k in 40..64 {
            assert_eq!(rows.obj[k], 0.0);
            assert!(rows.um.row(k).iter().all(|&u| u == 0.0));
        }
    }
}
