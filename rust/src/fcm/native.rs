//! Pure-rust [`ChunkBackend`] — the same math as the Pallas kernels
//! (`python/compile/kernels/fcm_pallas.py`), validated against the AOT
//! golden vectors in `rust/tests/integration_runtime.rs`.
//!
//! Used by: the driver job (tiny sample, not worth a PJRT round-trip),
//! unit tests, and as the `Backend::Native` ablation arm.
//!
//! ## Kernel layout (EXPERIMENTS.md §Perf)
//!
//! The hot entry points (`fcm_partials_native`, `classic_partials_native`,
//! `kmeans_partials_native`) run a **tiled distance pass**: records are
//! processed in [`TILE_ROWS`]-row tiles against a transposed (d × C) center
//! panel, so the innermost loop walks one contiguous f32 slice of center
//! components per dimension — independent f32 lanes the autovectorizer maps
//! straight onto SIMD registers. Distances accumulate in f32 lanes
//! (squared-difference form — no ‖x‖²−2x·v+‖v‖² cancellation) and are
//! promoted to f64 at the tile boundary, where the membership reduction
//! runs exactly as the scalar reference. `powf` dominates the generic path,
//! so the paper's default m=2 (p = 1, u^m = x⁻²) takes a
//! transcendental-free fast path everywhere.
//!
//! The original scalar per-row loops are kept verbatim as
//! `*_partials_scalar` — the correctness reference the tiled path is
//! property-tested against (`rust/tests/prop_invariants.rs`) and the
//! baseline arm of the `micro_hotpath` A/B.

use crate::data::matrix::dist2;
use crate::data::Matrix;
use crate::error::Result;
use crate::fcm::{ChunkBackend, Partials};
use crate::mapreduce::session::SlabState;

const DIST_EPS: f64 = 1e-12;

/// Default row-tile height of the tiled distance pass — the proven
/// mid-shape choice [`tile_rows_for`] falls back to. 8 rows × C f32 lanes
/// keeps the tile's distance block plus the center panel row in L1 across
/// the middle of the experiment matrix while giving the vectorizer long
/// independent lanes.
pub const TILE_ROWS: usize = 8;

/// Row-tile height for a (d, C) kernel shape (ROADMAP kernel follow-up:
/// autotune instead of the hardcoded 8).
///
/// The tile-resident working set is ≈ `tile × (C + d)` f32 — the tile's
/// distance block plus its row slab — sitting next to the (d × C) center
/// panel. The lookup sizes the tile so that set stays within about half a
/// 32 KiB L1 (2048 f32 slots) while giving the vectorizer the longest
/// lanes the shape affords: skinny shapes (small d·C) take tall tiles for
/// panel reuse, fat shapes shrink the tile to stay cache-resident. The
/// scalar oracles remain the property-test reference for every bucket, so
/// the lookup can only move speed, never results beyond f32-lane rounding.
pub fn tile_rows_for(d: usize, c: usize) -> usize {
    let per_row = (c + d).max(1);
    match 2048 / per_row {
        0..=7 => 4,
        8..=15 => TILE_ROWS,
        16..=31 => 16,
        _ => 32,
    }
}

/// The native backend is stateless.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend
    }
}

impl ChunkBackend for NativeBackend {
    fn fcm_partials(&self, x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Result<Partials> {
        Ok(fcm_partials_native(x, v, w, m))
    }

    fn classic_partials(&self, x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Result<Partials> {
        Ok(classic_partials_native(x, v, w, m))
    }

    fn kmeans_partials(&self, x: &Matrix, v: &Matrix, w: &[f32]) -> Result<Partials> {
        Ok(kmeans_partials_native(x, v, w))
    }

    #[allow(clippy::too_many_arguments)]
    fn fcm_partials_pruned(
        &self,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
        state: &mut BlockPruneState,
        tol: f64,
        refresh_every: usize,
    ) -> Result<(Partials, usize)> {
        Ok(fcm_partials_pruned(x, v, w, m, state, tol, refresh_every))
    }

    #[allow(clippy::too_many_arguments)]
    fn classic_partials_pruned(
        &self,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
        state: &mut BlockPruneState,
        tol: f64,
        refresh_every: usize,
    ) -> Result<(Partials, usize)> {
        Ok(classic_partials_pruned(x, v, w, m, state, tol, refresh_every))
    }

    fn kmeans_partials_pruned(
        &self,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        state: &mut BlockPruneState,
        tol: f64,
        refresh_every: usize,
    ) -> Result<(Partials, usize)> {
        Ok(kmeans_partials_pruned(x, v, w, state, tol, refresh_every))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// f32-lane squared-distance pass over one row tile.
///
/// `rows` is a `t × d` row-major slab, `panel` the (d × C) transposed center
/// matrix; on return `out[r·C + i] = Σ_j (rows[r][j] − v[i][j])²`. Each
/// row's lane accumulates in the same j-order regardless of its position in
/// the tile, so per-record distances are bit-identical under any row split —
/// the combiner-associativity property the engine relies on.
fn tile_dist2(rows: &[f32], t: usize, d: usize, panel: &Matrix, out: &mut [f32]) {
    let c = panel.cols();
    debug_assert_eq!(panel.rows(), d);
    debug_assert_eq!(rows.len(), t * d);
    debug_assert_eq!(out.len(), t * c);
    for acc in out.iter_mut() {
        *acc = 0.0;
    }
    for j in 0..d {
        let pj = panel.row(j); // component j of every center, contiguous
        for r in 0..t {
            let xrj = rows[r * d + j];
            let lane = &mut out[r * c..(r + 1) * c];
            for (acc, &vj) in lane.iter_mut().zip(pj) {
                let diff = xrj - vj;
                *acc += diff * diff;
            }
        }
    }
}

/// Fast-FCM partials (Kolen–Hutcheson), tiled: computes u^m directly from
/// the distance vector of each record — O(C·d) per record, no membership
/// matrix. Distances come from the f32-lane tile pass; the membership
/// reduction is f64 per record, matching [`fcm_partials_scalar`] to f32
/// rounding (property-tested in `prop_invariants.rs`).
pub fn fcm_partials_native(x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Partials {
    let (c, d) = (v.rows(), v.cols());
    debug_assert_eq!(x.rows(), w.len());
    let mut out = Partials::zeros(c, d);
    if c == 0 {
        return out;
    }
    let p = 1.0 / (m - 1.0);
    let m2 = m == 2.0; // p = 1, (num·den)^-m = 1/(num·den)²
    let panel = v.transposed();
    let tile = tile_rows_for(d, c);
    // Scratch reused across tiles to keep the hot loop allocation-free.
    let mut d2t = vec![0.0f32; tile * c];
    let mut num = vec![0.0f64; c];
    let mut d2v = vec![0.0f64; c];
    for (base, t, rows) in x.iter_row_tiles(tile) {
        tile_dist2(rows, t, d, &panel, &mut d2t[..t * c]);
        for r in 0..t {
            let wk = w[base + r] as f64;
            if wk == 0.0 {
                continue; // padding contract
            }
            // f64 reduction at the tile boundary. Memberships depend only on
            // distance ratios; normalising by the row minimum before powering
            // avoids under/overflow at small m (matches the Pallas kernel,
            // fcm_pallas._um_fast).
            let lane = &d2t[r * c..(r + 1) * c];
            let mut dmin = f64::INFINITY;
            for i in 0..c {
                let d2 = (lane[i] as f64).max(DIST_EPS);
                d2v[i] = d2;
                dmin = dmin.min(d2);
            }
            let mut den = 0.0f64;
            if m2 {
                for i in 0..c {
                    let n = d2v[i] / dmin;
                    num[i] = n;
                    den += 1.0 / n;
                }
            } else {
                for i in 0..c {
                    let n = (d2v[i] / dmin).powf(p);
                    num[i] = n;
                    den += 1.0 / n;
                }
            }
            let row = &rows[r * d..(r + 1) * d];
            for i in 0..c {
                let um = if m2 {
                    let nd = num[i] * den;
                    wk / (nd * nd)
                } else {
                    (num[i] * den).powf(-m) * wk
                };
                out.w_acc[i] += um;
                out.objective += um * d2v[i];
                let umf = um as f32;
                let vrow = out.v_num.row_mut(i);
                for (val, &xj) in vrow.iter_mut().zip(row) {
                    *val += umf * xj;
                }
            }
        }
    }
    out
}

/// Classic-FCM partials, tiled: the explicit O(C²) ratio sum per record —
/// the "basic FCM" complexity the paper contrasts against (and the compute
/// model of the Mahout FKM baseline; the pair loop is kept so that model
/// stays honest). Powered distances are hoisted out of the pair loop:
/// `powf` cost is C per record instead of C².
pub fn classic_partials_native(x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Partials {
    let (c, d) = (v.rows(), v.cols());
    let mut out = Partials::zeros(c, d);
    if c == 0 {
        return out;
    }
    let p = 1.0 / (m - 1.0);
    let m2 = m == 2.0;
    let panel = v.transposed();
    let tile = tile_rows_for(d, c);
    let mut d2t = vec![0.0f32; tile * c];
    let mut d2v = vec![0.0f64; c];
    let mut dp = vec![0.0f64; c];
    for (base, t, rows) in x.iter_row_tiles(tile) {
        tile_dist2(rows, t, d, &panel, &mut d2t[..t * c]);
        for r in 0..t {
            let wk = w[base + r] as f64;
            if wk == 0.0 {
                continue;
            }
            let lane = &d2t[r * c..(r + 1) * c];
            let mut dmin = f64::INFINITY;
            for i in 0..c {
                let d2 = (lane[i] as f64).max(DIST_EPS);
                d2v[i] = d2;
                dmin = dmin.min(d2);
            }
            // powf hoist: dp[i] = (d_i/dmin)^p once per (record, cluster);
            // the dmin normalisation keeps dp ≥ ~1 so ratios cannot
            // overflow, and it cancels in dp[i]/dp[j] below.
            if m2 {
                for i in 0..c {
                    dp[i] = d2v[i] / dmin;
                }
            } else {
                for i in 0..c {
                    dp[i] = (d2v[i] / dmin).powf(p);
                }
            }
            let row = &rows[r * d..(r + 1) * d];
            for i in 0..c {
                // u_i = 1 / Σ_j (d_i/d_j)^p — the textbook double loop,
                // over precomputed powers.
                let mut s = 0.0f64;
                for j in 0..c {
                    s += dp[i] / dp[j];
                }
                let u = 1.0 / s;
                let um = if m2 { u * u * wk } else { u.powf(m) * wk };
                out.w_acc[i] += um;
                out.objective += um * d2v[i];
                let vrow = out.v_num.row_mut(i);
                for (jj, val) in vrow.iter_mut().enumerate() {
                    *val += (um * row[jj] as f64) as f32;
                }
            }
        }
    }
    out
}

/// Hard K-Means partials, tiled: per-cluster weighted sums/counts + SSE.
pub fn kmeans_partials_native(x: &Matrix, v: &Matrix, w: &[f32]) -> Partials {
    let (c, d) = (v.rows(), v.cols());
    let mut out = Partials::zeros(c, d);
    if c == 0 {
        return out;
    }
    let panel = v.transposed();
    let tile = tile_rows_for(d, c);
    let mut d2t = vec![0.0f32; tile * c];
    for (base, t, rows) in x.iter_row_tiles(tile) {
        tile_dist2(rows, t, d, &panel, &mut d2t[..t * c]);
        for r in 0..t {
            let wk = w[base + r] as f64;
            if wk == 0.0 {
                continue;
            }
            let lane = &d2t[r * c..(r + 1) * c];
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for (i, &d2) in lane.iter().enumerate() {
                let dd = (d2 as f64).max(DIST_EPS);
                if dd < best_d {
                    best_d = dd;
                    best = i;
                }
            }
            out.w_acc[best] += wk;
            out.objective += wk * best_d;
            let row = &rows[r * d..(r + 1) * d];
            let vrow = out.v_num.row_mut(best);
            for (j, val) in vrow.iter_mut().enumerate() {
                *val += (wk * row[j] as f64) as f32;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Shift-bounded pruning (iteration-resident sessions)
// ---------------------------------------------------------------------------

/// Which FCM flavor a pruned pass computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum FcmFlavor {
    Fast,
    Classic,
}

/// Sticky per-block state for shift-bounded pruning — Elkan/Hamerly in
/// spirit, adapted to fuzzy memberships: each record caches its
/// nearest-center distance `d_min` and its last exactly-computed
/// contribution; the block caches per-center displacement accumulated
/// since its last full refresh plus the whole block's latest partials.
///
/// The bound: memberships depend only on distance *ratios*, and after the
/// centers move by accumulated displacements `δ_j` every distance changes
/// by at most `δ_max = max_j δ_j` (triangle inequality), i.e. by a factor
/// within `1 ± δ_max / d_min` of its cached value. While
/// `δ_max ≤ tol × d_min(record)` holds, the record's membership vector is
/// perturbed by O(tol) and its cached contribution is reused; drift is
/// bounded by the session's periodic full refresh (`refresh_every`).
/// `δ_max` accumulates *path length* since the block's last full refresh,
/// which upper-bounds the movement since any later per-record refresh —
/// so mixed passes stay conservative. For K-Means the per-record bound is
/// the classic margin test `2·δ_max ≤ d₂ − d₁`, under which the cached
/// assignment — and therefore the record's exact `w_acc`/`v_num`
/// contribution — cannot change (only its objective term is stale).
///
/// Lives in a session's [`crate::mapreduce::session::StateSlab`], keyed by
/// block id and byte-accounted via [`SlabState`].
#[derive(Clone, Debug)]
pub struct BlockPruneState {
    /// Centers seen by the most recent pass (for shift accumulation).
    centers_prev: Matrix,
    /// Per-center displacement accumulated since the last full refresh.
    delta_acc: Vec<f64>,
    /// Per-record nearest-center distance (Euclidean) at that record's
    /// last exact pass; `INFINITY` for zero-weight padding records.
    d_min: Vec<f32>,
    /// `min` of `d_min` over the block — the whole-block prune bound.
    d_min_block: f32,
    /// Per-record cached contribution u^m·w per center (n × C), FCM only.
    um: Matrix,
    /// Per-record cached objective contribution.
    obj: Vec<f32>,
    /// Per-record cached nearest-center assignment (K-Means only).
    best: Vec<u32>,
    /// Per-record runner-up margin `d₂ − d₁` (K-Means only).
    margin: Vec<f32>,
    /// `min` of `margin` over the block (K-Means whole-block bound).
    margin_block: f32,
    /// The block's latest partials (whole-block prune reuses these).
    partials: Option<Partials>,
    /// Live (non-zero-weight) records counted at the last refresh — the
    /// whole-block pruned count, cached so that path never scans rows.
    /// (Pruning assumes per-record weights are stable across the session,
    /// which the session loop's uniform weights guarantee.)
    live: usize,
    /// Passes since the last full refresh.
    stale_iters: usize,
}

impl Default for BlockPruneState {
    fn default() -> Self {
        Self {
            centers_prev: Matrix::zeros(0, 0),
            delta_acc: Vec::new(),
            d_min: Vec::new(),
            d_min_block: f32::INFINITY,
            um: Matrix::zeros(0, 0),
            obj: Vec::new(),
            best: Vec::new(),
            margin: Vec::new(),
            margin_block: f32::INFINITY,
            partials: None,
            live: 0,
            stale_iters: 0,
        }
    }
}

impl BlockPruneState {
    /// Drop every cached bound: the next pass is exact and refreshing.
    pub fn reset(&mut self) {
        *self = Self::default();
    }

    /// Whether any bounds are currently cached.
    pub fn is_fresh(&self) -> bool {
        self.partials.is_some()
    }

    /// Byte footprint for slab accounting.
    pub fn bytes(&self) -> u64 {
        let f32s = self.d_min.len()
            + self.obj.len()
            + self.margin.len()
            + self.um.rows() * self.um.cols()
            + self.centers_prev.rows() * self.centers_prev.cols();
        let partials = self.partials.as_ref().map(Partials::encoded_bytes).unwrap_or(0);
        (f32s * 4 + self.delta_acc.len() * 8 + self.best.len() * 4) as u64 + partials
    }
}

impl SlabState for BlockPruneState {
    fn slab_bytes(&self) -> u64 {
        self.bytes()
    }
}

/// Fast-FCM partials with shift-bounded pruning against `state`. Returns
/// the partials and how many records reused their cached contribution.
/// `tol` is the relative distance-perturbation tolerance (≤ 0 disables
/// pruning — every pass is exact and refreshing); `refresh_every` caps
/// passes between full refreshes, bounding pruned-vs-exact drift.
pub fn fcm_partials_pruned(
    x: &Matrix,
    v: &Matrix,
    w: &[f32],
    m: f64,
    state: &mut BlockPruneState,
    tol: f64,
    refresh_every: usize,
) -> (Partials, usize) {
    fcm_like_pruned(x, v, w, m, FcmFlavor::Fast, state, tol, refresh_every)
}

/// Classic-FCM partials with shift-bounded pruning (see
/// [`fcm_partials_pruned`]).
pub fn classic_partials_pruned(
    x: &Matrix,
    v: &Matrix,
    w: &[f32],
    m: f64,
    state: &mut BlockPruneState,
    tol: f64,
    refresh_every: usize,
) -> (Partials, usize) {
    fcm_like_pruned(x, v, w, m, FcmFlavor::Classic, state, tol, refresh_every)
}

/// Fold the centers' movement since the previous pass into the per-center
/// accumulated displacement; returns the largest accumulated value. Path
/// length since the block's last full refresh upper-bounds the movement
/// since any later per-record refresh, keeping mixed passes conservative.
fn accumulate_shift(state: &mut BlockPruneState, v: &Matrix) -> f64 {
    let mut worst = 0.0f64;
    for j in 0..v.rows() {
        let step = dist2(state.centers_prev.row(j), v.row(j)).sqrt();
        state.delta_acc[j] += step;
        worst = worst.max(state.delta_acc[j]);
    }
    state.centers_prev = v.clone();
    worst
}

#[allow(clippy::too_many_arguments)]
fn fcm_like_pruned(
    x: &Matrix,
    v: &Matrix,
    w: &[f32],
    m: f64,
    flavor: FcmFlavor,
    state: &mut BlockPruneState,
    tol: f64,
    refresh_every: usize,
) -> (Partials, usize) {
    let (n, c, d) = (x.rows(), v.rows(), v.cols());
    debug_assert_eq!(n, w.len());
    let refresh_every = refresh_every.max(1);
    let usable = tol > 0.0
        && c > 0
        && state.partials.is_some()
        && state.d_min.len() == n
        && state.um.rows() == n
        && state.um.cols() == c
        && state.centers_prev.rows() == c
        && state.centers_prev.cols() == d
        && state.stale_iters < refresh_every;
    if !usable {
        return (fcm_like_refresh(x, v, w, m, flavor, state), 0);
    }
    state.stale_iters += 1;
    let delta_max = accumulate_shift(state, v);
    // Whole-block bound: every live record's perturbation is within
    // tolerance — reuse the cached block partials, touching no record
    // (O(C·d) total: the shift fold plus one partials clone).
    if delta_max <= tol * state.d_min_block as f64 {
        let p = state.partials.clone().expect("usable implies cached partials");
        return (p, state.live);
    }

    // Mixed pass: records still inside their bound replay their cached
    // contribution (no distance tile, no powf); the rest are gathered into
    // compact tiles and recomputed exactly, refreshing their cached state.
    let p_exp = 1.0 / (m - 1.0);
    let m2 = m == 2.0;
    let panel = v.transposed();
    let tile = tile_rows_for(d, c);
    let mut out = Partials::zeros(c, d);
    let mut pruned = 0usize;
    let mut d2t = vec![0.0f32; tile * c];
    let mut d2v = vec![0.0f64; c];
    let mut um_buf = vec![0.0f64; c];
    let mut scratch = vec![0.0f64; c];
    let mut batch_rows: Vec<f32> = Vec::with_capacity(tile * d);
    let mut batch_idx: Vec<usize> = Vec::with_capacity(tile);
    let mut d_min_block = f32::INFINITY;
    let thr = delta_max / tol;
    for k in 0..n {
        if w[k] == 0.0 {
            continue; // padding contract
        }
        if (state.d_min[k] as f64) >= thr {
            let row = x.row(k);
            let um_row = state.um.row(k);
            for (i, &u) in um_row.iter().enumerate() {
                out.w_acc[i] += u as f64;
                let vrow = out.v_num.row_mut(i);
                for (val, &xj) in vrow.iter_mut().zip(row) {
                    *val += u * xj;
                }
            }
            out.objective += state.obj[k] as f64;
            d_min_block = d_min_block.min(state.d_min[k]);
            pruned += 1;
        } else {
            batch_idx.push(k);
            batch_rows.extend_from_slice(x.row(k));
            if batch_idx.len() == tile {
                fcm_flush_batch(
                    &batch_rows,
                    &batch_idx,
                    d,
                    &panel,
                    m,
                    p_exp,
                    m2,
                    flavor,
                    w,
                    &mut d2t,
                    &mut d2v,
                    &mut um_buf,
                    &mut scratch,
                    &mut out,
                    state,
                    &mut d_min_block,
                );
                batch_rows.clear();
                batch_idx.clear();
            }
        }
    }
    if !batch_idx.is_empty() {
        fcm_flush_batch(
            &batch_rows,
            &batch_idx,
            d,
            &panel,
            m,
            p_exp,
            m2,
            flavor,
            w,
            &mut d2t,
            &mut d2v,
            &mut um_buf,
            &mut scratch,
            &mut out,
            state,
            &mut d_min_block,
        );
    }
    state.d_min_block = d_min_block;
    state.partials = Some(out.clone());
    (out, pruned)
}

/// Exact gathered pass over one batch of unpruned records: distance tile,
/// membership reduction, accumulation — and a refresh of each record's
/// cached `d_min`/contribution against the current centers.
#[allow(clippy::too_many_arguments)]
fn fcm_flush_batch(
    rows: &[f32],
    idx: &[usize],
    d: usize,
    panel: &Matrix,
    m: f64,
    p_exp: f64,
    m2: bool,
    flavor: FcmFlavor,
    w: &[f32],
    d2t: &mut [f32],
    d2v: &mut [f64],
    um: &mut [f64],
    scratch: &mut [f64],
    out: &mut Partials,
    state: &mut BlockPruneState,
    d_min_block: &mut f32,
) {
    let c = panel.cols();
    let t = idx.len();
    tile_dist2(rows, t, d, panel, &mut d2t[..t * c]);
    for r in 0..t {
        let k = idx[r];
        let wk = w[k] as f64;
        let lane = &d2t[r * c..(r + 1) * c];
        let mut dmin = f64::INFINITY;
        for (i, &dl) in lane.iter().enumerate() {
            let dd = (dl as f64).max(DIST_EPS);
            d2v[i] = dd;
            dmin = dmin.min(dd);
        }
        compute_um(flavor, p_exp, m, m2, d2v, dmin, wk, um, scratch);
        let row = &rows[r * d..(r + 1) * d];
        let mut obj_k = 0.0f64;
        let um_row = state.um.row_mut(k);
        for i in 0..c {
            let u = um[i];
            out.w_acc[i] += u;
            obj_k += u * d2v[i];
            let uf = u as f32;
            um_row[i] = uf;
            let vrow = out.v_num.row_mut(i);
            for (val, &xj) in vrow.iter_mut().zip(row) {
                *val += uf * xj;
            }
        }
        out.objective += obj_k;
        state.obj[k] = obj_k as f32;
        let de = dmin.sqrt() as f32;
        state.d_min[k] = de;
        *d_min_block = (*d_min_block).min(de);
    }
}

/// Per-record u^m·w weights. Fast = the Kolen–Hutcheson normalised form,
/// Classic = the textbook O(C²) ratio sum over hoisted powers — matching
/// the respective exact kernels' math (and their m = 2 fast paths).
#[allow(clippy::too_many_arguments)]
fn compute_um(
    flavor: FcmFlavor,
    p_exp: f64,
    m: f64,
    m2: bool,
    d2v: &[f64],
    dmin: f64,
    wk: f64,
    um: &mut [f64],
    scratch: &mut [f64],
) {
    let c = d2v.len();
    match flavor {
        FcmFlavor::Fast => {
            let mut den = 0.0f64;
            for i in 0..c {
                let nrm = if m2 { d2v[i] / dmin } else { (d2v[i] / dmin).powf(p_exp) };
                scratch[i] = nrm;
                den += 1.0 / nrm;
            }
            for i in 0..c {
                um[i] = if m2 {
                    let nd = scratch[i] * den;
                    wk / (nd * nd)
                } else {
                    (scratch[i] * den).powf(-m) * wk
                };
            }
        }
        FcmFlavor::Classic => {
            for i in 0..c {
                scratch[i] = if m2 { d2v[i] / dmin } else { (d2v[i] / dmin).powf(p_exp) };
            }
            for i in 0..c {
                let mut s = 0.0f64;
                for j in 0..c {
                    s += scratch[i] / scratch[j];
                }
                let u = 1.0 / s;
                um[i] = if m2 { u * u * wk } else { u.powf(m) * wk };
            }
        }
    }
}

/// Full exact pass that (re)builds every cached bound: the fallback for
/// empty/mismatched state, disabled pruning, and the periodic refresh.
fn fcm_like_refresh(
    x: &Matrix,
    v: &Matrix,
    w: &[f32],
    m: f64,
    flavor: FcmFlavor,
    state: &mut BlockPruneState,
) -> Partials {
    let (n, c, d) = (x.rows(), v.rows(), v.cols());
    state.centers_prev = v.clone();
    state.delta_acc = vec![0.0; c];
    state.stale_iters = 0;
    state.d_min = vec![f32::INFINITY; n];
    state.um = Matrix::zeros(n, c);
    state.obj = vec![0.0; n];
    state.best = Vec::new();
    state.margin = Vec::new();
    state.margin_block = f32::INFINITY;
    state.live = w.iter().filter(|&&wk| wk != 0.0).count();
    let mut out = Partials::zeros(c, d);
    if c == 0 {
        state.d_min_block = f32::INFINITY;
        state.partials = Some(out.clone());
        return out;
    }
    let p_exp = 1.0 / (m - 1.0);
    let m2 = m == 2.0;
    let panel = v.transposed();
    let tile = tile_rows_for(d, c);
    let mut d2t = vec![0.0f32; tile * c];
    let mut d2v = vec![0.0f64; c];
    let mut um_buf = vec![0.0f64; c];
    let mut scratch = vec![0.0f64; c];
    let mut batch_rows: Vec<f32> = Vec::with_capacity(tile * d);
    let mut batch_idx: Vec<usize> = Vec::with_capacity(tile);
    let mut d_min_block = f32::INFINITY;
    for k in 0..n {
        if w[k] == 0.0 {
            continue; // padding contract
        }
        batch_idx.push(k);
        batch_rows.extend_from_slice(x.row(k));
        if batch_idx.len() == tile {
            fcm_flush_batch(
                &batch_rows,
                &batch_idx,
                d,
                &panel,
                m,
                p_exp,
                m2,
                flavor,
                w,
                &mut d2t,
                &mut d2v,
                &mut um_buf,
                &mut scratch,
                &mut out,
                state,
                &mut d_min_block,
            );
            batch_rows.clear();
            batch_idx.clear();
        }
    }
    if !batch_idx.is_empty() {
        fcm_flush_batch(
            &batch_rows,
            &batch_idx,
            d,
            &panel,
            m,
            p_exp,
            m2,
            flavor,
            w,
            &mut d2t,
            &mut d2v,
            &mut um_buf,
            &mut scratch,
            &mut out,
            state,
            &mut d_min_block,
        );
    }
    state.d_min_block = d_min_block;
    state.partials = Some(out.clone());
    out
}

/// Hard K-Means partials with shift-bounded pruning: while
/// `2·δ_max ≤ margin` the cached assignment cannot change, making the
/// pruned `w_acc`/`v_num` contributions *exact* (only the objective term
/// is stale, refreshed by the periodic exact pass). `tol > 0` merely
/// enables pruning — the bound itself is absolute.
pub fn kmeans_partials_pruned(
    x: &Matrix,
    v: &Matrix,
    w: &[f32],
    state: &mut BlockPruneState,
    tol: f64,
    refresh_every: usize,
) -> (Partials, usize) {
    let (n, c, d) = (x.rows(), v.rows(), v.cols());
    debug_assert_eq!(n, w.len());
    let refresh_every = refresh_every.max(1);
    let usable = tol > 0.0
        && c > 0
        && state.partials.is_some()
        && state.best.len() == n
        && state.margin.len() == n
        && state.obj.len() == n
        && state.centers_prev.rows() == c
        && state.centers_prev.cols() == d
        && state.stale_iters < refresh_every;
    if !usable {
        return (kmeans_refresh(x, v, w, state), 0);
    }
    state.stale_iters += 1;
    let delta_max = accumulate_shift(state, v);
    if 2.0 * delta_max <= state.margin_block as f64 {
        let p = state.partials.clone().expect("usable implies cached partials");
        return (p, state.live);
    }

    let panel = v.transposed();
    let tile = tile_rows_for(d, c);
    let mut out = Partials::zeros(c, d);
    let mut pruned = 0usize;
    let mut d2t = vec![0.0f32; tile * c];
    let mut batch_rows: Vec<f32> = Vec::with_capacity(tile * d);
    let mut batch_idx: Vec<usize> = Vec::with_capacity(tile);
    let mut margin_block = f32::INFINITY;
    let two_delta = 2.0 * delta_max;
    for k in 0..n {
        if w[k] == 0.0 {
            continue;
        }
        if two_delta <= state.margin[k] as f64 {
            let wk = w[k] as f64;
            let best = state.best[k] as usize;
            out.w_acc[best] += wk;
            out.objective += state.obj[k] as f64;
            let row = x.row(k);
            let vrow = out.v_num.row_mut(best);
            for (j, val) in vrow.iter_mut().enumerate() {
                *val += (wk * row[j] as f64) as f32;
            }
            margin_block = margin_block.min(state.margin[k]);
            pruned += 1;
        } else {
            batch_idx.push(k);
            batch_rows.extend_from_slice(x.row(k));
            if batch_idx.len() == tile {
                kmeans_flush_batch(
                    &batch_rows,
                    &batch_idx,
                    d,
                    &panel,
                    w,
                    &mut d2t,
                    &mut out,
                    state,
                    &mut margin_block,
                );
                batch_rows.clear();
                batch_idx.clear();
            }
        }
    }
    if !batch_idx.is_empty() {
        kmeans_flush_batch(
            &batch_rows,
            &batch_idx,
            d,
            &panel,
            w,
            &mut d2t,
            &mut out,
            state,
            &mut margin_block,
        );
    }
    state.margin_block = margin_block;
    state.partials = Some(out.clone());
    (out, pruned)
}

/// Exact gathered K-Means batch: argmin + runner-up margin per record,
/// refreshing the cached assignment bounds.
#[allow(clippy::too_many_arguments)]
fn kmeans_flush_batch(
    rows: &[f32],
    idx: &[usize],
    d: usize,
    panel: &Matrix,
    w: &[f32],
    d2t: &mut [f32],
    out: &mut Partials,
    state: &mut BlockPruneState,
    margin_block: &mut f32,
) {
    let c = panel.cols();
    let t = idx.len();
    tile_dist2(rows, t, d, panel, &mut d2t[..t * c]);
    for r in 0..t {
        let k = idx[r];
        let wk = w[k] as f64;
        let lane = &d2t[r * c..(r + 1) * c];
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        let mut second_d = f64::INFINITY;
        for (i, &dl) in lane.iter().enumerate() {
            let dd = (dl as f64).max(DIST_EPS);
            if dd < best_d {
                second_d = best_d;
                best_d = dd;
                best = i;
            } else if dd < second_d {
                second_d = dd;
            }
        }
        out.w_acc[best] += wk;
        out.objective += wk * best_d;
        let row = &rows[r * d..(r + 1) * d];
        let vrow = out.v_num.row_mut(best);
        for (j, val) in vrow.iter_mut().enumerate() {
            *val += (wk * row[j] as f64) as f32;
        }
        state.best[k] = best as u32;
        let margin = if second_d.is_finite() {
            (second_d.sqrt() - best_d.sqrt()) as f32
        } else {
            f32::INFINITY // C = 1: the assignment can never change
        };
        state.margin[k] = margin;
        state.obj[k] = (wk * best_d) as f32;
        *margin_block = (*margin_block).min(margin);
    }
}

/// Full exact K-Means pass that (re)builds every cached assignment bound.
fn kmeans_refresh(x: &Matrix, v: &Matrix, w: &[f32], state: &mut BlockPruneState) -> Partials {
    let (n, c, d) = (x.rows(), v.rows(), v.cols());
    state.centers_prev = v.clone();
    state.delta_acc = vec![0.0; c];
    state.stale_iters = 0;
    state.d_min = Vec::new();
    state.d_min_block = f32::INFINITY;
    state.um = Matrix::zeros(0, 0);
    state.obj = vec![0.0; n];
    state.best = vec![0; n];
    state.margin = vec![f32::INFINITY; n];
    state.live = w.iter().filter(|&&wk| wk != 0.0).count();
    let mut out = Partials::zeros(c, d);
    if c == 0 {
        state.margin_block = f32::INFINITY;
        state.partials = Some(out.clone());
        return out;
    }
    let panel = v.transposed();
    let tile = tile_rows_for(d, c);
    let mut d2t = vec![0.0f32; tile * c];
    let mut batch_rows: Vec<f32> = Vec::with_capacity(tile * d);
    let mut batch_idx: Vec<usize> = Vec::with_capacity(tile);
    let mut margin_block = f32::INFINITY;
    for k in 0..n {
        if w[k] == 0.0 {
            continue;
        }
        batch_idx.push(k);
        batch_rows.extend_from_slice(x.row(k));
        if batch_idx.len() == tile {
            kmeans_flush_batch(
                &batch_rows,
                &batch_idx,
                d,
                &panel,
                w,
                &mut d2t,
                &mut out,
                state,
                &mut margin_block,
            );
            batch_rows.clear();
            batch_idx.clear();
        }
    }
    if !batch_idx.is_empty() {
        kmeans_flush_batch(
            &batch_rows,
            &batch_idx,
            d,
            &panel,
            w,
            &mut d2t,
            &mut out,
            state,
            &mut margin_block,
        );
    }
    state.margin_block = margin_block;
    state.partials = Some(out.clone());
    out
}

// ---------------------------------------------------------------------------
// Scalar reference kernels
// ---------------------------------------------------------------------------

/// Scalar fast-FCM reference: per-row f64 distances, no tiling. This is the
/// pre-optimization hot path, kept verbatim as the oracle the tiled kernel
/// is property-tested against and as the `micro_hotpath` A/B baseline.
pub fn fcm_partials_scalar(x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Partials {
    let (c, d) = (v.rows(), v.cols());
    debug_assert_eq!(x.rows(), w.len());
    let mut out = Partials::zeros(c, d);
    let p = 1.0 / (m - 1.0);
    let m2 = m == 2.0;
    let mut num = vec![0.0f64; c];
    let mut d2v = vec![0.0f64; c];
    for (k, row) in x.iter_rows().enumerate() {
        let wk = w[k] as f64;
        if wk == 0.0 {
            continue; // padding contract
        }
        let mut dmin = f64::INFINITY;
        for i in 0..c {
            let d2 = dist2(row, v.row(i)).max(DIST_EPS);
            d2v[i] = d2;
            dmin = dmin.min(d2);
        }
        let mut den = 0.0f64;
        if m2 {
            for i in 0..c {
                let n = d2v[i] / dmin;
                num[i] = n;
                den += 1.0 / n;
            }
        } else {
            for i in 0..c {
                let n = (d2v[i] / dmin).powf(p);
                num[i] = n;
                den += 1.0 / n;
            }
        }
        for i in 0..c {
            let um = if m2 {
                let nd = num[i] * den;
                wk / (nd * nd)
            } else {
                (num[i] * den).powf(-m) * wk
            };
            out.w_acc[i] += um;
            out.objective += um * d2v[i];
            let umf = um as f32;
            let vrow = out.v_num.row_mut(i);
            for (val, &xj) in vrow.iter_mut().zip(row) {
                *val += umf * xj;
            }
        }
    }
    out
}

/// Scalar classic-FCM reference: the textbook O(C²) double loop with a
/// `powf` per (i, j) pair — exactly the pre-hoist formulation.
pub fn classic_partials_scalar(x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Partials {
    let (c, d) = (v.rows(), v.cols());
    let mut out = Partials::zeros(c, d);
    let p = 1.0 / (m - 1.0);
    let mut d2v = vec![0.0f64; c];
    for (k, row) in x.iter_rows().enumerate() {
        let wk = w[k] as f64;
        if wk == 0.0 {
            continue;
        }
        for i in 0..c {
            d2v[i] = dist2(row, v.row(i)).max(DIST_EPS);
        }
        for i in 0..c {
            // u_i = 1 / Σ_j (d_i/d_j)^p — the textbook double loop.
            let mut s = 0.0f64;
            for j in 0..c {
                s += (d2v[i] / d2v[j]).powf(p);
            }
            let u = 1.0 / s;
            let um = u.powf(m) * wk;
            out.w_acc[i] += um;
            out.objective += um * d2v[i];
            let vrow = out.v_num.row_mut(i);
            for (jj, val) in vrow.iter_mut().enumerate() {
                *val += (um * row[jj] as f64) as f32;
            }
        }
    }
    out
}

/// Scalar hard K-Means reference.
pub fn kmeans_partials_scalar(x: &Matrix, v: &Matrix, w: &[f32]) -> Partials {
    let (c, d) = (v.rows(), v.cols());
    let mut out = Partials::zeros(c, d);
    for (k, row) in x.iter_rows().enumerate() {
        let wk = w[k] as f64;
        if wk == 0.0 {
            continue;
        }
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for i in 0..c {
            let dd = dist2(row, v.row(i)).max(DIST_EPS);
            if dd < best_d {
                best_d = dd;
                best = i;
            }
        }
        out.w_acc[best] += wk;
        out.objective += wk * best_d;
        let vrow = out.v_num.row_mut(best);
        for (j, val) in vrow.iter_mut().enumerate() {
            *val += (wk * row[j] as f64) as f32;
        }
    }
    out
}

/// Full membership matrix (N, C) — used by quality metrics, not the hot
/// path. Still worth the m=2 fast path: silhouette/confusion passes over
/// large N would otherwise pay a `powf` per (record, cluster).
pub fn memberships(x: &Matrix, v: &Matrix, m: f64) -> Matrix {
    let (n, c) = (x.rows(), v.rows());
    let p = 1.0 / (m - 1.0);
    let m2 = m == 2.0; // p = 1: ratios need no powering
    let mut u = Matrix::zeros(n, c);
    let mut num = vec![0.0f64; c];
    let mut d2v = vec![0.0f64; c];
    for k in 0..n {
        let row = x.row(k);
        let mut dmin = f64::INFINITY;
        for i in 0..c {
            let d2 = dist2(row, v.row(i)).max(DIST_EPS);
            d2v[i] = d2;
            dmin = dmin.min(d2);
        }
        let mut den = 0.0f64;
        if m2 {
            for i in 0..c {
                let nm = d2v[i] / dmin;
                num[i] = nm;
                den += 1.0 / nm;
            }
        } else {
            for i in 0..c {
                let nm = (d2v[i] / dmin).powf(p);
                num[i] = nm;
                den += 1.0 / nm;
            }
        }
        for i in 0..c {
            u.set(k, i, (1.0 / (num[i] * den)) as f32);
        }
    }
    u
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg;

    fn rand_case(n: usize, d: usize, c: usize, seed: u64) -> (Matrix, Matrix, Vec<f32>) {
        let mut rng = Pcg::new(seed);
        let mut x = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                x.set(i, j, rng.normal() as f32);
            }
        }
        let mut v = Matrix::zeros(c, d);
        for i in 0..c {
            for j in 0..d {
                v.set(i, j, rng.normal() as f32);
            }
        }
        let w = (0..n).map(|_| rng.next_f32() + 0.1).collect();
        (x, v, w)
    }

    #[test]
    fn fast_equals_classic_partials() {
        // The Kolen–Hutcheson trick is algebraically identical to classic.
        let (x, v, w) = rand_case(200, 5, 4, 1);
        for m in [1.2, 2.0, 2.8] {
            let a = fcm_partials_native(&x, &v, &w, m);
            let b = classic_partials_native(&x, &v, &w, m);
            for (p, q) in a.v_num.as_slice().iter().zip(b.v_num.as_slice()) {
                assert!((p - q).abs() < 1e-3, "{p} vs {q} at m={m}");
            }
            for (p, q) in a.w_acc.iter().zip(&b.w_acc) {
                assert!((p - q).abs() < 1e-6);
            }
            assert!((a.objective - b.objective).abs() / b.objective.max(1e-9) < 1e-6);
        }
    }

    #[test]
    fn tiled_matches_scalar_reference() {
        // Awkward shapes: tail tiles (n % TILE_ROWS ≠ 0), d=1, C=1.
        for (n, d, c, seed) in [(67, 5, 4, 11), (8, 1, 3, 12), (13, 7, 1, 13), (256, 18, 6, 14)] {
            let (x, v, w) = rand_case(n, d, c, seed);
            for m in [1.2, 2.0, 2.8] {
                let a = fcm_partials_native(&x, &v, &w, m);
                let b = fcm_partials_scalar(&x, &v, &w, m);
                for (p, q) in a.v_num.as_slice().iter().zip(b.v_num.as_slice()) {
                    assert!((p - q).abs() <= 1e-3 + 1e-4 * q.abs(), "{p} vs {q} m={m} n={n}");
                }
                for (p, q) in a.w_acc.iter().zip(&b.w_acc) {
                    assert!((p - q).abs() <= 1e-6 + 1e-4 * q.abs(), "{p} vs {q} m={m} n={n}");
                }
                let rel = (a.objective - b.objective).abs() / b.objective.max(1e-9);
                assert!(rel < 1e-4, "objective {rel} m={m} n={n}");
            }
        }
    }

    #[test]
    fn classic_hoist_matches_scalar_reference() {
        let (x, v, w) = rand_case(100, 4, 5, 21);
        for m in [1.2, 2.0, 2.8] {
            let a = classic_partials_native(&x, &v, &w, m);
            let b = classic_partials_scalar(&x, &v, &w, m);
            for (p, q) in a.w_acc.iter().zip(&b.w_acc) {
                assert!((p - q).abs() <= 1e-6 + 1e-4 * q.abs(), "{p} vs {q} at m={m}");
            }
            let rel = (a.objective - b.objective).abs() / b.objective.max(1e-9);
            assert!(rel < 1e-4, "objective diverged: {rel} at m={m}");
        }
    }

    #[test]
    fn memberships_rows_sum_to_one() {
        let (x, v, _) = rand_case(100, 4, 3, 2);
        for m in [1.5, 2.0, 3.0] {
            let u = memberships(&x, &v, m);
            for i in 0..u.rows() {
                let s: f32 = u.row(i).iter().sum();
                assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s} at m={m}");
            }
        }
    }

    #[test]
    fn memberships_fast_path_matches_generic_at_m2() {
        // The m=2 shortcut must be the identical distribution, only cheaper.
        // 2.0 + tiny epsilon forces the generic powf arm for comparison.
        let (x, v, _) = rand_case(80, 3, 4, 6);
        let fast = memberships(&x, &v, 2.0);
        let generic = memberships(&x, &v, 2.0 + 1e-12);
        for (a, b) in fast.as_slice().iter().zip(generic.as_slice()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_weight_records_ignored() {
        let (x, v, mut w) = rand_case(64, 3, 2, 3);
        for wk in w.iter_mut().skip(32) {
            *wk = 0.0;
        }
        let full = fcm_partials_native(&x, &v, &w, 2.0);
        // Corrupt ignored rows; result must be identical.
        let mut x2 = x.clone();
        for i in 32..64 {
            for j in 0..3 {
                x2.set(i, j, 1e9);
            }
        }
        let same = fcm_partials_native(&x2, &v, &w, 2.0);
        assert_eq!(full.v_num.as_slice(), same.v_num.as_slice());
        assert_eq!(full.w_acc, same.w_acc);
    }

    #[test]
    fn partials_associativity() {
        let (x, v, w) = rand_case(128, 4, 3, 4);
        let full = fcm_partials_native(&x, &v, &w, 2.0);
        let mut merged = fcm_partials_native(&x.slice_rows(0, 64), &v, &w[..64], 2.0);
        let right = fcm_partials_native(&x.slice_rows(64, 128), &v, &w[64..], 2.0);
        merged.merge(&right);
        for (a, b) in merged.v_num.as_slice().iter().zip(full.v_num.as_slice()) {
            assert!((a - b).abs() < 1e-3);
        }
        for (a, b) in merged.w_acc.iter().zip(&full.w_acc) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn partials_associativity_unaligned_split() {
        // Split off the tile grid: per-record tile_dist2 lanes must not
        // depend on a row's position within its tile.
        let (x, v, w) = rand_case(61, 5, 4, 9);
        let full = fcm_partials_native(&x, &v, &w, 2.0);
        let mut merged = fcm_partials_native(&x.slice_rows(0, 29), &v, &w[..29], 2.0);
        merged.merge(&fcm_partials_native(&x.slice_rows(29, 61), &v, &w[29..], 2.0));
        for (a, b) in merged.v_num.as_slice().iter().zip(full.v_num.as_slice()) {
            assert!((a - b).abs() < 1e-3);
        }
        for (a, b) in merged.w_acc.iter().zip(&full.w_acc) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn kmeans_counts_sum_to_weight_mass() {
        let (x, v, w) = rand_case(256, 6, 5, 5);
        let p = kmeans_partials_native(&x, &v, &w);
        let total_w: f64 = w.iter().map(|&x| x as f64).sum();
        let total_c: f64 = p.w_acc.iter().sum();
        assert!((total_w - total_c).abs() < 1e-6);
    }

    #[test]
    fn kmeans_tiled_matches_scalar_on_separated_data() {
        // Hand-built well-separated clusters: the argmin margin dwarfs f32
        // rounding (a tiled/scalar flip would need a record equidistant to
        // two centers within f32 eps), so per-cluster sums must agree.
        let (c, d, n) = (4usize, 4usize, 500usize);
        let mut rng = Pcg::new(31);
        let mut v = Matrix::zeros(c, d);
        for i in 0..c {
            v.set(i, i % d, 8.0 * (i as f32 + 1.0));
        }
        let mut x = Matrix::zeros(n, d);
        for k in 0..n {
            let home = k % c;
            for j in 0..d {
                x.set(k, j, v.get(home, j) + (rng.normal() * 0.3) as f32);
            }
        }
        let w: Vec<f32> = (0..n).map(|i| 0.5 + (i % 5) as f32 * 0.3).collect();
        let a = kmeans_partials_native(&x, &v, &w);
        let b = kmeans_partials_scalar(&x, &v, &w);
        for (p, q) in a.w_acc.iter().zip(&b.w_acc) {
            assert!((p - q).abs() < 1e-9, "{p} vs {q}");
        }
        for (p, q) in a.v_num.as_slice().iter().zip(b.v_num.as_slice()) {
            assert!((p - q).abs() <= 1e-3 + 1e-4 * q.abs(), "{p} vs {q}");
        }
        let rel = (a.objective - b.objective).abs() / b.objective.max(1e-9);
        assert!(rel < 1e-4, "objective diverged: {rel}");
    }

    #[test]
    fn point_on_center_is_finite() {
        let x = Matrix::from_rows(&[vec![1.0, 1.0], vec![3.0, 3.0]]);
        let v = Matrix::from_rows(&[vec![1.0, 1.0], vec![5.0, 5.0]]);
        let p = fcm_partials_native(&x, &v, &[1.0, 1.0], 2.0);
        assert!(p.v_num.as_slice().iter().all(|v| v.is_finite()));
        assert!(p.w_acc.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn tile_autotune_buckets_are_sane() {
        // Skinny shapes take tall tiles, fat shapes shrink — and the
        // default lives in the middle.
        assert_eq!(tile_rows_for(1, 1), 32);
        assert_eq!(tile_rows_for(18, 6), 32); // SUSY shape
        assert_eq!(tile_rows_for(41, 50), 16); // widest experiment shape
        assert_eq!(tile_rows_for(120, 40), TILE_ROWS);
        assert_eq!(tile_rows_for(260, 40), 4);
        for (d, c) in [(1, 1), (3, 7), (41, 50), (120, 40), (260, 40)] {
            let t = tile_rows_for(d, c);
            assert!([4, 8, 16, 32].contains(&t), "odd tile {t} for ({d}, {c})");
        }
    }

    #[test]
    fn kernels_match_oracle_across_tile_buckets() {
        // One shape per autotune bucket: the tiled result must agree with
        // the scalar oracle whatever tile the lookup picks.
        for (d, c) in [(2, 3), (41, 50), (150, 30), (280, 20)] {
            let (x, v, w) = rand_case(73, d, c, 99);
            let a = fcm_partials_native(&x, &v, &w, 2.0);
            let b = fcm_partials_scalar(&x, &v, &w, 2.0);
            for (p, q) in a.w_acc.iter().zip(&b.w_acc) {
                assert!((p - q).abs() <= 1e-6 + 1e-4 * q.abs(), "({d},{c}): {p} vs {q}");
            }
            let rel = (a.objective - b.objective).abs() / b.objective.max(1e-9);
            assert!(rel < 1e-4, "({d},{c}): objective rel {rel}");
        }
    }

    #[test]
    fn pruned_first_pass_is_exact_refresh() {
        let (x, v, w) = rand_case(120, 5, 4, 41);
        for m in [1.4, 2.0] {
            let mut state = BlockPruneState::default();
            let (p, pruned) = fcm_partials_pruned(&x, &v, &w, m, &mut state, 1e-2, 4);
            assert_eq!(pruned, 0, "first pass must refresh, not prune");
            assert!(state.is_fresh());
            let exact = fcm_partials_native(&x, &v, &w, m);
            for (a, b) in p.w_acc.iter().zip(&exact.w_acc) {
                assert!((a - b).abs() <= 1e-6 + 1e-4 * b.abs(), "m={m}: {a} vs {b}");
            }
            let rel = (p.objective - exact.objective).abs() / exact.objective.max(1e-9);
            assert!(rel < 1e-4, "m={m}: objective rel {rel}");
        }
    }

    #[test]
    fn unmoved_centers_prune_whole_block() {
        let (x, v, w) = rand_case(100, 4, 3, 42);
        let mut state = BlockPruneState::default();
        let (first, _) = fcm_partials_pruned(&x, &v, &w, 2.0, &mut state, 1e-2, 8);
        // Same centers again: zero shift → whole block served from cache.
        let (second, pruned) = fcm_partials_pruned(&x, &v, &w, 2.0, &mut state, 1e-2, 8);
        assert_eq!(pruned, 100);
        assert_eq!(first.w_acc, second.w_acc);
        assert_eq!(first.v_num.as_slice(), second.v_num.as_slice());
        assert_eq!(first.objective, second.objective);
    }

    #[test]
    fn refresh_cap_forces_exact_pass() {
        let (x, v, w) = rand_case(80, 3, 3, 43);
        let mut state = BlockPruneState::default();
        fcm_partials_pruned(&x, &v, &w, 2.0, &mut state, 1e-2, 2);
        let (_, p1) = fcm_partials_pruned(&x, &v, &w, 2.0, &mut state, 1e-2, 2);
        assert_eq!(p1, 80, "within the cap the unmoved block prunes");
        let (_, p2) = fcm_partials_pruned(&x, &v, &w, 2.0, &mut state, 1e-2, 2);
        assert_eq!(p2, 80);
        // stale_iters hit the cap: next pass must be a refresh.
        let (_, p3) = fcm_partials_pruned(&x, &v, &w, 2.0, &mut state, 1e-2, 2);
        assert_eq!(p3, 0, "refresh_every must force an exact pass");
    }

    #[test]
    fn zero_tolerance_disables_pruning() {
        let (x, v, w) = rand_case(64, 3, 3, 44);
        let mut state = BlockPruneState::default();
        for _ in 0..3 {
            let (_, pruned) = fcm_partials_pruned(&x, &v, &w, 2.0, &mut state, 0.0, 4);
            assert_eq!(pruned, 0);
        }
    }

    #[test]
    fn small_shift_prunes_and_stays_close_to_exact() {
        // Well-separated blobs → comfortable d_min; a tiny center nudge
        // must prune most records while the pruned partials stay within
        // the membership-perturbation bound of the exact ones.
        let data = crate::data::synth::blobs(400, 3, 3, 0.2, 45);
        let x = &data.features;
        let w = vec![1.0f32; 400];
        let mut v = Matrix::zeros(3, 3);
        for i in 0..3 {
            v.row_mut(i).copy_from_slice(x.row(i * 133));
        }
        let mut state = BlockPruneState::default();
        let tol = 1e-2;
        fcm_partials_pruned(x, &v, &w, 2.0, &mut state, tol, 8);
        // Nudge every center by a displacement far below tol × d_min.
        let mut v2 = v.clone();
        for val in v2.as_mut_slice().iter_mut() {
            *val += 1e-5;
        }
        let (pruned_p, pruned_n) = fcm_partials_pruned(x, &v2, &w, 2.0, &mut state, tol, 8);
        assert!(pruned_n > 300, "tiny shift should prune most records, got {pruned_n}");
        let exact = fcm_partials_native(x, &v2, &w, 2.0);
        for (a, b) in pruned_p.w_acc.iter().zip(&exact.w_acc) {
            let rel = (a - b).abs() / b.abs().max(1e-9);
            assert!(rel < 10.0 * tol, "pruned w_acc drift {rel} vs {b}");
        }
        let rel = (pruned_p.objective - exact.objective).abs() / exact.objective.max(1e-9);
        assert!(rel < 10.0 * tol, "pruned objective drift {rel}");
    }

    #[test]
    fn classic_pruned_matches_classic_exact_on_refresh() {
        let (x, v, w) = rand_case(90, 4, 4, 46);
        for m in [1.3, 2.0] {
            let mut state = BlockPruneState::default();
            let (p, pruned) = classic_partials_pruned(&x, &v, &w, m, &mut state, 1e-2, 4);
            assert_eq!(pruned, 0);
            let exact = classic_partials_native(&x, &v, &w, m);
            for (a, b) in p.w_acc.iter().zip(&exact.w_acc) {
                assert!((a - b).abs() <= 1e-6 + 1e-4 * b.abs(), "m={m}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn kmeans_pruned_center_update_is_exact_under_small_shift() {
        // Separated clusters: small center movement cannot flip any
        // assignment, so pruned w_acc / v_num must equal the exact pass
        // bit-for-bit (only the objective may lag).
        let (c, d, n) = (3usize, 4usize, 300usize);
        let mut rng = Pcg::new(47);
        let mut v = Matrix::zeros(c, d);
        for i in 0..c {
            v.set(i, i % d, 10.0 * (i as f32 + 1.0));
        }
        let mut x = Matrix::zeros(n, d);
        for k in 0..n {
            let home = k % c;
            for j in 0..d {
                x.set(k, j, v.get(home, j) + (rng.normal() * 0.2) as f32);
            }
        }
        let w = vec![1.0f32; n];
        let mut state = BlockPruneState::default();
        kmeans_partials_pruned(&x, &v, &w, &mut state, 1e-2, 8);
        let mut v2 = v.clone();
        for val in v2.as_mut_slice().iter_mut() {
            *val += 0.01;
        }
        let (pruned_p, pruned_n) = kmeans_partials_pruned(&x, &v2, &w, &mut state, 1e-2, 8);
        assert!(pruned_n > 0, "margin test should prune on separated data");
        let exact = kmeans_partials_native(&x, &v2, &w);
        assert_eq!(pruned_p.w_acc, exact.w_acc, "pruned K-Means masses must be exact");
        for (a, b) in pruned_p.v_num.as_slice().iter().zip(exact.v_num.as_slice()) {
            assert!((a - b).abs() <= 1e-4 + 1e-5 * b.abs(), "{a} vs {b}");
        }
    }

    #[test]
    fn pruned_state_tracks_bytes() {
        let (x, v, w) = rand_case(50, 3, 4, 48);
        let mut state = BlockPruneState::default();
        assert_eq!(state.bytes(), 0);
        fcm_partials_pruned(&x, &v, &w, 2.0, &mut state, 1e-2, 4);
        // d_min + obj (n each) + um (n×C) + centers + partials, in bytes.
        assert!(state.bytes() > (50 * (4 + 4) + 50 * 4 * 4) as u64);
        state.reset();
        assert_eq!(state.bytes(), 0);
        assert!(!state.is_fresh());
    }
}
