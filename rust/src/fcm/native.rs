//! Pure-rust [`ChunkBackend`] — the same math as the Pallas kernels
//! (`python/compile/kernels/fcm_pallas.py`), validated against the AOT
//! golden vectors in `rust/tests/integration_runtime.rs`.
//!
//! Used by: the driver job (tiny sample, not worth a PJRT round-trip),
//! unit tests, and as the `Backend::Native` ablation arm.

use crate::data::matrix::dist2;
use crate::data::Matrix;
use crate::error::Result;
use crate::fcm::{ChunkBackend, Partials};

const DIST_EPS: f64 = 1e-12;

/// The native backend is stateless.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend
    }
}

impl ChunkBackend for NativeBackend {
    fn fcm_partials(&self, x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Result<Partials> {
        Ok(fcm_partials_native(x, v, w, m))
    }

    fn classic_partials(&self, x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Result<Partials> {
        Ok(classic_partials_native(x, v, w, m))
    }

    fn kmeans_partials(&self, x: &Matrix, v: &Matrix, w: &[f32]) -> Result<Partials> {
        Ok(kmeans_partials_native(x, v, w))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Fast-FCM partials (Kolen–Hutcheson): computes u^m directly from the
/// distance vector of each record — O(C·d) per record, no membership matrix.
///
/// Perf (EXPERIMENTS.md §Perf): `powf` dominates the generic path, so the
/// paper's default m=2 (p = 1, u^m = x⁻²) takes a transcendental-free fast
/// path — ~3.6× throughput on the 65k-record micro-bench.
pub fn fcm_partials_native(x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Partials {
    let (c, d) = (v.rows(), v.cols());
    debug_assert_eq!(x.rows(), w.len());
    let mut out = Partials::zeros(c, d);
    let p = 1.0 / (m - 1.0);
    let m2 = m == 2.0; // p = 1, (num·den)^-m = 1/(num·den)²
    // Scratch reused across records to keep the hot loop allocation-free.
    let mut num = vec![0.0f64; c];
    let mut d2v = vec![0.0f64; c];
    for (k, row) in x.iter_rows().enumerate() {
        let wk = w[k] as f64;
        if wk == 0.0 {
            continue; // padding contract
        }
        // Memberships depend only on distance ratios; normalising by the row
        // minimum before powering avoids under/overflow at small m (matches
        // the Pallas kernel, fcm_pallas._um_fast).
        let mut dmin = f64::INFINITY;
        for i in 0..c {
            let d2 = dist2(row, v.row(i)).max(DIST_EPS);
            d2v[i] = d2;
            dmin = dmin.min(d2);
        }
        let mut den = 0.0f64;
        if m2 {
            for i in 0..c {
                let n = d2v[i] / dmin;
                num[i] = n;
                den += 1.0 / n;
            }
        } else {
            for i in 0..c {
                let n = (d2v[i] / dmin).powf(p);
                num[i] = n;
                den += 1.0 / n;
            }
        }
        for i in 0..c {
            let um = if m2 {
                let nd = num[i] * den;
                wk / (nd * nd)
            } else {
                (num[i] * den).powf(-m) * wk
            };
            out.w_acc[i] += um;
            out.objective += um * d2v[i];
            let umf = um as f32;
            let vrow = out.v_num.row_mut(i);
            for (val, &xj) in vrow.iter_mut().zip(row) {
                *val += umf * xj;
            }
        }
    }
    out
}

/// Classic-FCM partials: explicit O(C²) ratio sums per record — the
/// "basic FCM" complexity the paper contrasts against (and the compute
/// model of the Mahout FKM baseline).
pub fn classic_partials_native(x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Partials {
    let (c, d) = (v.rows(), v.cols());
    let mut out = Partials::zeros(c, d);
    let p = 1.0 / (m - 1.0);
    let mut d2v = vec![0.0f64; c];
    for (k, row) in x.iter_rows().enumerate() {
        let wk = w[k] as f64;
        if wk == 0.0 {
            continue;
        }
        for i in 0..c {
            d2v[i] = dist2(row, v.row(i)).max(DIST_EPS);
        }
        for i in 0..c {
            // u_i = 1 / Σ_j (d_i/d_j)^p — the textbook double loop.
            let mut s = 0.0f64;
            for j in 0..c {
                s += (d2v[i] / d2v[j]).powf(p);
            }
            let u = 1.0 / s;
            let um = u.powf(m) * wk;
            out.w_acc[i] += um;
            out.objective += um * d2v[i];
            let vrow = out.v_num.row_mut(i);
            for (jj, val) in vrow.iter_mut().enumerate() {
                *val += (um * row[jj] as f64) as f32;
            }
        }
    }
    out
}

/// Hard K-Means partials: per-cluster weighted sums/counts + SSE.
pub fn kmeans_partials_native(x: &Matrix, v: &Matrix, w: &[f32]) -> Partials {
    let (c, d) = (v.rows(), v.cols());
    let mut out = Partials::zeros(c, d);
    for (k, row) in x.iter_rows().enumerate() {
        let wk = w[k] as f64;
        if wk == 0.0 {
            continue;
        }
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for i in 0..c {
            let dd = dist2(row, v.row(i)).max(DIST_EPS);
            if dd < best_d {
                best_d = dd;
                best = i;
            }
        }
        out.w_acc[best] += wk;
        out.objective += wk * best_d;
        let vrow = out.v_num.row_mut(best);
        for (j, val) in vrow.iter_mut().enumerate() {
            *val += (wk * row[j] as f64) as f32;
        }
    }
    out
}

/// Full membership matrix (N, C) — used by quality metrics, not the hot path.
pub fn memberships(x: &Matrix, v: &Matrix, m: f64) -> Matrix {
    let (n, c) = (x.rows(), v.rows());
    let p = 1.0 / (m - 1.0);
    let mut u = Matrix::zeros(n, c);
    let mut num = vec![0.0f64; c];
    let mut d2v = vec![0.0f64; c];
    for k in 0..n {
        let row = x.row(k);
        let mut dmin = f64::INFINITY;
        for i in 0..c {
            let d2 = dist2(row, v.row(i)).max(DIST_EPS);
            d2v[i] = d2;
            dmin = dmin.min(d2);
        }
        let mut den = 0.0f64;
        for i in 0..c {
            let nm = (d2v[i] / dmin).powf(p);
            num[i] = nm;
            den += 1.0 / nm;
        }
        for i in 0..c {
            u.set(k, i, (1.0 / (num[i] * den)) as f32);
        }
    }
    u
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg;

    fn rand_case(n: usize, d: usize, c: usize, seed: u64) -> (Matrix, Matrix, Vec<f32>) {
        let mut rng = Pcg::new(seed);
        let mut x = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                x.set(i, j, rng.normal() as f32);
            }
        }
        let mut v = Matrix::zeros(c, d);
        for i in 0..c {
            for j in 0..d {
                v.set(i, j, rng.normal() as f32);
            }
        }
        let w = (0..n).map(|_| rng.next_f32() + 0.1).collect();
        (x, v, w)
    }

    #[test]
    fn fast_equals_classic_partials() {
        // The Kolen–Hutcheson trick is algebraically identical to classic.
        let (x, v, w) = rand_case(200, 5, 4, 1);
        for m in [1.2, 2.0, 2.8] {
            let a = fcm_partials_native(&x, &v, &w, m);
            let b = classic_partials_native(&x, &v, &w, m);
            for (p, q) in a.v_num.as_slice().iter().zip(b.v_num.as_slice()) {
                assert!((p - q).abs() < 1e-3, "{p} vs {q} at m={m}");
            }
            for (p, q) in a.w_acc.iter().zip(&b.w_acc) {
                assert!((p - q).abs() < 1e-6);
            }
            assert!((a.objective - b.objective).abs() / b.objective.max(1e-9) < 1e-6);
        }
    }

    #[test]
    fn memberships_rows_sum_to_one() {
        let (x, v, _) = rand_case(100, 4, 3, 2);
        let u = memberships(&x, &v, 2.0);
        for i in 0..u.rows() {
            let s: f32 = u.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
        }
    }

    #[test]
    fn zero_weight_records_ignored() {
        let (x, v, mut w) = rand_case(64, 3, 2, 3);
        for wk in w.iter_mut().skip(32) {
            *wk = 0.0;
        }
        let full = fcm_partials_native(&x, &v, &w, 2.0);
        // Corrupt ignored rows; result must be identical.
        let mut x2 = x.clone();
        for i in 32..64 {
            for j in 0..3 {
                x2.set(i, j, 1e9);
            }
        }
        let same = fcm_partials_native(&x2, &v, &w, 2.0);
        assert_eq!(full.v_num.as_slice(), same.v_num.as_slice());
        assert_eq!(full.w_acc, same.w_acc);
    }

    #[test]
    fn partials_associativity() {
        let (x, v, w) = rand_case(128, 4, 3, 4);
        let full = fcm_partials_native(&x, &v, &w, 2.0);
        let mut merged = fcm_partials_native(&x.slice_rows(0, 64), &v, &w[..64], 2.0);
        let right = fcm_partials_native(&x.slice_rows(64, 128), &v, &w[64..], 2.0);
        merged.merge(&right);
        for (a, b) in merged.v_num.as_slice().iter().zip(full.v_num.as_slice()) {
            assert!((a - b).abs() < 1e-3);
        }
        for (a, b) in merged.w_acc.iter().zip(&full.w_acc) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn kmeans_counts_sum_to_weight_mass() {
        let (x, v, w) = rand_case(256, 6, 5, 5);
        let p = kmeans_partials_native(&x, &v, &w);
        let total_w: f64 = w.iter().map(|&x| x as f64).sum();
        let total_c: f64 = p.w_acc.iter().sum();
        assert!((total_w - total_c).abs() < 1e-6);
    }

    #[test]
    fn point_on_center_is_finite() {
        let x = Matrix::from_rows(&[vec![1.0, 1.0], vec![3.0, 3.0]]);
        let v = Matrix::from_rows(&[vec![1.0, 1.0], vec![5.0, 5.0]]);
        let p = fcm_partials_native(&x, &v, &[1.0, 1.0], 2.0);
        assert!(p.v_num.as_slice().iter().all(|v| v.is_finite()));
        assert!(p.w_acc.iter().all(|v| v.is_finite()));
    }
}
