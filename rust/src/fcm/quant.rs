//! Quantized distance pre-pass: i8 block sidecars with certified error
//! radii, the layer *underneath* the bound models of [`super::backend`].
//!
//! ## The idea
//!
//! The shift-bounded tests (`dmin`/`elkan`/`hamerly`) compare a center's
//! accumulated **path length** δ_j against the refresh-time distances.
//! Path length overcharges net displacement — a center that wanders and
//! returns keeps a large δ_j forever (until the refresh cap) even though
//! no distance actually changed. The pre-pass gives those records a
//! second chance: an i8-quantized copy of the block (one-time sidecar,
//! symmetric per-column scales) yields *current* approximate distances
//! d̃² plus a certified radius E with `|d² − d̃²| ≤ E`, so a record can be
//! re-certified against the cached bounds from the interval
//! `[√(d̃²−E), √(d̃²+E)]` alone — no f32 row math, no powf. Exact math
//! runs only for records neither the δ bound nor the interval clears.
//!
//! ## The certificate
//!
//! Per column `t` the sidecar stores a scale `s_t = max_i|x_it|/127` and
//! codes `q_it = round(x_it/s_t)` (exact in i8: `|x/s| ≤ 127` by
//! construction), so `x_it = s_t·q_it + e_it` with `|e_it| ≤ s_t/2`. Per
//! pass each center row is coded once as `c_jt = round(v_jt/s_t)`
//! (clamped i16) with the **exact** residual `f_jt = v_jt − s_t·c_jt`
//! kept — the bound below uses the actual `|f_jt|`, so clamping never
//! breaks soundness. Writing the per-coordinate difference as
//! `s_t·Δq + (e − f)` with `|e − f| ≤ g_jt := s_t/2 + |f_jt|`:
//!
//! ```text
//! |d² − d̃²| ≤ Σ_t 2·s_t·g_jt·|Δq_t|  +  Σ_t g_jt²      (= A + G_j)
//! ```
//!
//! where `d̃² = Σ_t s_t²·Δq_t²`. The kernel accumulates `Δq` in exact i32
//! and the weighted sums in f64; `E` is then inflated by generous float
//! headroom (`1e-9` relative on `A + G`, `1e-6` relative on `d̃²` — the
//! exact kernels subtract coordinates in f32, a `2⁻²⁴`-relative effect
//! the inflation dominates) so the certificate also covers the *computed*
//! distances the cached bounds came from. `prop_invariants` pins the
//! inequality against random shapes and scales.

use crate::data::Matrix;
use crate::fcm::backend::{put_blob, put_f32s, put_u32, Cur};

/// One block's i8 quantization: row-major codes plus symmetric per-column
/// scales. Built lazily on a block's first quant-enabled pass, owned by
/// the block's [`super::BlockBounds`] (byte-accounted, spillable), and
/// immutable thereafter — it depends only on the block payload, so it
/// survives bound refreshes and center movement.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantSidecar {
    n: usize,
    d: usize,
    /// Row-major i8 codes, n × d.
    codes: Vec<i8>,
    /// Per-column scale `s_t = max_i|x_it|/127` (0 for an all-zero column).
    scales: Vec<f32>,
}

/// Per-pass quantization of one center matrix against a sidecar's scales:
/// i16 codes plus the exact-residual error terms of the certificate. Tiny
/// (O(C·d)) and rebuilt every pass — centers move, the sidecar doesn't.
pub struct QuantCenters {
    c: usize,
    d: usize,
    /// Row-major i16 codes, C × d (0 where the column scale is 0).
    codes: Vec<i16>,
    /// `a_jt = 2·s_t·g_jt` — the |Δq| weights of the error sum, C × d.
    a: Vec<f64>,
    /// `G_j = Σ_t g_jt²` — the Δq-independent error floor, length C.
    g2: Vec<f64>,
    /// `s_t²` in f64 (exact squares of the f32 scales), length d.
    s2: Vec<f64>,
}

impl QuantCenters {
    pub fn clusters(&self) -> usize {
        self.c
    }
}

impl QuantSidecar {
    /// Quantize a block: one pass for the column maxima, one for the codes.
    pub fn build(x: &Matrix) -> Self {
        let (n, d) = (x.rows(), x.cols());
        let mut scales = vec![0.0f32; d];
        for row in x.iter_rows() {
            for (s, &xv) in scales.iter_mut().zip(row) {
                *s = s.max(xv.abs());
            }
        }
        for s in scales.iter_mut() {
            *s /= 127.0;
        }
        let mut codes = vec![0i8; n * d];
        for (chunk, row) in codes.chunks_exact_mut(d.max(1)).zip(x.iter_rows()) {
            for ((q, &xv), &s) in chunk.iter_mut().zip(row).zip(&scales) {
                if s > 0.0 {
                    *q = (xv / s).round().clamp(-127.0, 127.0) as i8;
                }
            }
        }
        Self { n, d, codes, scales }
    }

    /// Whether this sidecar quantizes a block of the given shape.
    pub fn matches(&self, n: usize, d: usize) -> bool {
        self.n == n && self.d == d
    }

    /// Byte footprint for slab accounting: codes + scales + header.
    pub fn bytes(&self) -> u64 {
        (self.codes.len() + self.scales.len() * 4 + 16) as u64
    }

    /// Code one center matrix against this sidecar's scales, precomputing
    /// every Δq-independent term of the error certificate.
    pub fn prep_centers(&self, v: &Matrix) -> QuantCenters {
        debug_assert_eq!(v.cols(), self.d);
        let (c, d) = (v.rows(), self.d);
        let mut codes = vec![0i16; c * d];
        let mut a = vec![0.0f64; c * d];
        let mut g2 = vec![0.0f64; c];
        for j in 0..c {
            let vrow = v.row(j);
            let crow = &mut codes[j * d..(j + 1) * d];
            let arow = &mut a[j * d..(j + 1) * d];
            let mut acc = 0.0f64;
            for t in 0..d {
                let s = self.scales[t] as f64;
                let vjt = vrow[t] as f64;
                let code =
                    if s > 0.0 { (vjt / s).round().clamp(-32767.0, 32767.0) as i16 } else { 0 };
                crow[t] = code;
                // Exact residual after the (possibly clamped) rounding —
                // the certificate uses the actual |f|, so an out-of-range
                // center only widens its own interval.
                let f = vjt - s * code as f64;
                let g = 0.5 * s + f.abs();
                arow[t] = 2.0 * s * g;
                acc += g * g;
            }
            g2[j] = acc;
        }
        let s2 = self.scales.iter().map(|&s| s as f64 * s as f64).collect();
        QuantCenters { c, d, codes, a, g2, s2 }
    }

    /// Approximate squared distances of record `k` to every center plus
    /// the certified radius: `|d²_j − d2[j]| ≤ err[j]` for the exact
    /// kernels' computed (pre-clamp) distances. Δq runs in exact i32; the
    /// scale-weighted sums accumulate in f64.
    pub fn row_distances(&self, k: usize, qc: &QuantCenters, d2: &mut [f64], err: &mut [f64]) {
        debug_assert_eq!(qc.d, self.d);
        debug_assert_eq!(d2.len(), qc.c);
        debug_assert_eq!(err.len(), qc.c);
        let q = &self.codes[k * self.d..(k + 1) * self.d];
        for j in 0..qc.c {
            let cj = &qc.codes[j * self.d..(j + 1) * self.d];
            let aj = &qc.a[j * self.d..(j + 1) * self.d];
            let mut approx = 0.0f64;
            let mut spread = 0.0f64;
            for t in 0..self.d {
                let dq = q[t] as i32 - cj[t] as i32;
                approx += qc.s2[t] * (dq * dq) as f64;
                spread += aj[t] * dq.unsigned_abs() as f64;
            }
            d2[j] = approx;
            err[j] = (spread + qc.g2[j]) * (1.0 + 1e-9) + 1e-6 * approx + 1e-12;
        }
    }

    /// Approximate squared distances only — the candidate-selection form
    /// the bulk scorer uses, where top-k slack absorbs the error instead
    /// of a per-center certificate.
    pub fn row_approx(&self, k: usize, qc: &QuantCenters, d2: &mut [f64]) {
        debug_assert_eq!(qc.d, self.d);
        debug_assert_eq!(d2.len(), qc.c);
        let q = &self.codes[k * self.d..(k + 1) * self.d];
        for j in 0..qc.c {
            let cj = &qc.codes[j * self.d..(j + 1) * self.d];
            let mut approx = 0.0f64;
            for t in 0..self.d {
                let dq = q[t] as i32 - cj[t] as i32;
                approx += qc.s2[t] * (dq * dq) as f64;
            }
            d2[j] = approx;
        }
    }

    /// Append this sidecar to a spill image (codes travel as raw bytes,
    /// scales as exact LE bit patterns — the roundtrip is bitwise).
    pub(crate) fn encode(&self, b: &mut Vec<u8>) {
        put_u32(b, self.n as u32);
        put_u32(b, self.d as u32);
        let raw: Vec<u8> = self.codes.iter().map(|&q| q as u8).collect();
        put_blob(b, &raw);
        put_f32s(b, &self.scales);
    }

    pub(crate) fn decode(c: &mut Cur) -> Option<Self> {
        let n = c.u32()? as usize;
        let d = c.u32()? as usize;
        let raw = c.blob()?;
        if raw.len() != n.checked_mul(d)? {
            return None;
        }
        let codes: Vec<i8> = raw.iter().map(|&b| b as i8).collect();
        let scales = c.f32s()?;
        if scales.len() != d {
            return None;
        }
        Some(Self { n, d, codes, scales })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg;

    fn rand_block(n: usize, d: usize, scale: f32, seed: u64) -> Matrix {
        let mut rng = Pcg::new(seed);
        let mut x = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                x.set(i, j, rng.normal() as f32 * scale);
            }
        }
        x
    }

    #[test]
    fn codes_reconstruct_within_half_step() {
        let x = rand_block(64, 5, 3.0, 7);
        let q = QuantSidecar::build(&x);
        for k in 0..64 {
            for t in 0..5 {
                let s = q.scales[t];
                let back = s * q.codes[k * 5 + t] as f32;
                assert!(
                    (x.get(k, t) - back).abs() <= 0.5 * s + 1e-6,
                    "record {k} col {t}: {} vs {back} (s={s})",
                    x.get(k, t)
                );
            }
        }
    }

    #[test]
    fn zero_column_gets_zero_scale_and_codes() {
        let mut x = rand_block(20, 3, 1.0, 8);
        for k in 0..20 {
            x.set(k, 1, 0.0);
        }
        let q = QuantSidecar::build(&x);
        assert_eq!(q.scales[1], 0.0);
        assert!((0..20).all(|k| q.codes[k * 3 + 1] == 0));
        // A center with mass in the dead column still gets a sound (wide)
        // interval: g absorbs the whole coordinate.
        let v = Matrix::from_rows(&[vec![0.5, 2.0, -0.25]]);
        let qc = q.prep_centers(&v);
        let (mut d2, mut err) = (vec![0.0], vec![0.0]);
        for k in 0..20 {
            q.row_distances(k, &qc, &mut d2, &mut err);
            let exact = x.row_dist2(k, v.row(0));
            assert!((exact - d2[0]).abs() <= err[0], "k={k}: |{exact}-{}| > {}", d2[0], err[0]);
        }
    }

    #[test]
    fn certificate_contains_exact_distance() {
        for (seed, n, d, c, xs, vs) in
            [(11u64, 80, 4, 3, 1.0f32, 1.0f32), (12, 50, 7, 5, 40.0, 55.0), (13, 30, 2, 4, 0.01, 3.0)]
        {
            let x = rand_block(n, d, xs, seed);
            let v = rand_block(c, d, vs, seed ^ 0xFF);
            let q = QuantSidecar::build(&x);
            let qc = q.prep_centers(&v);
            let mut d2 = vec![0.0; c];
            let mut err = vec![0.0; c];
            for k in 0..n {
                q.row_distances(k, &qc, &mut d2, &mut err);
                for j in 0..c {
                    let exact = x.row_dist2(k, v.row(j));
                    assert!(
                        (exact - d2[j]).abs() <= err[j],
                        "seed {seed} k={k} j={j}: |{exact} - {}| > {}",
                        d2[j],
                        err[j]
                    );
                }
            }
        }
    }

    #[test]
    fn approx_matches_certified_distances() {
        let x = rand_block(40, 6, 2.0, 21);
        let v = rand_block(4, 6, 2.0, 22);
        let q = QuantSidecar::build(&x);
        let qc = q.prep_centers(&v);
        let mut a = vec![0.0; 4];
        let mut d2 = vec![0.0; 4];
        let mut err = vec![0.0; 4];
        for k in 0..40 {
            q.row_approx(k, &qc, &mut a);
            q.row_distances(k, &qc, &mut d2, &mut err);
            assert_eq!(a, d2);
        }
    }

    #[test]
    fn codec_roundtrip_is_bitwise() {
        let x = rand_block(33, 5, 4.0, 31);
        let q = QuantSidecar::build(&x);
        let mut img = Vec::new();
        q.encode(&mut img);
        let mut cur = Cur::new(&img);
        let back = QuantSidecar::decode(&mut cur).expect("image decodes");
        assert!(cur.done());
        assert_eq!(q, back);
        let mut img2 = Vec::new();
        back.encode(&mut img2);
        assert_eq!(img, img2);
    }
}
