//! Figure 2 as a runnable scenario: how execution time responds to the
//! target precision (epsilon) for BigFCM vs the job-per-iteration Mahout
//! FKM baseline, with an ASCII rendering of the curves.
//!
//! ```bash
//! cargo run --release --example epsilon_sweep
//! ```

use std::sync::Arc;

use bigfcm::bench::tables::{fig2, Ctx};
use bigfcm::bench::Scale;
use bigfcm::config::Config;
use bigfcm::fcm::NativeBackend;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let ctx = Ctx::new(Config::default(), Scale::quick(), Arc::new(NativeBackend));
    println!("sweeping epsilon on SUSY-like data (C=2, m=2)...\n");
    let series = fig2(&ctx)?;

    println!("{:>10} | {:>14} | {:>14}", "epsilon", "BigFCM (s)", "Mahout FKM (s)");
    println!("{}", "-".repeat(46));
    for (eps, big, fkm) in &series {
        println!("{eps:>10.0e} | {big:>14.1} | {fkm:>14.1}");
    }

    // ASCII curve: log-ish bars scaled to the max.
    let max = series
        .iter()
        .map(|(_, b, f)| b.max(*f))
        .fold(0.0f64, f64::max);
    println!("\nmodelled time (each # ≈ {:.0}s)", max / 50.0);
    for (eps, big, fkm) in &series {
        let bar = |v: f64| "#".repeat(((v / max) * 50.0).ceil() as usize);
        println!("eps={eps:>7.0e}  BigFCM  {}", bar(*big));
        println!("             FKM     {}", bar(*fkm));
    }
    println!(
        "\nshape check (paper Fig. 2): the BigFCM bars stay flat while FKM grows as\n\
         epsilon tightens — BigFCM pays one MR job regardless of precision."
    );
    Ok(())
}
