//! End-to-end validation driver (EXPERIMENTS.md §E2E): exercises every
//! layer of the stack on a real small workload and reports the paper's
//! headline metric — speedup over job-per-iteration Mahout baselines at
//! equal-or-better clustering quality.
//!
//! Layers exercised:
//!   L1/L2 — AOT Pallas/JAX chunk graphs executed via PJRT (when
//!           `artifacts/` exists; falls back to the native backend with a
//!           notice otherwise),
//!   L3    — the full MapReduce pipeline: driver sampling + pre-clustering
//!           race, distributed cache, combiner FCM per block, WFCM reduce,
//!           fault injection on, plus both baselines on the same substrate.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use std::sync::Arc;

use bigfcm::baselines::{run_baseline, BaselineAlgo};
use bigfcm::config::Config;
use bigfcm::coordinator::BigFcm;
use bigfcm::data::builtin;
use bigfcm::fcm::{assign_hard, KernelBackend};
use bigfcm::hdfs::BlockStore;
use bigfcm::mapreduce::{Engine, EngineOptions};
use bigfcm::metrics::{confusion_accuracy, silhouette_width_sampled, speedup};
use bigfcm::prng::Pcg;
use bigfcm::runtime::ResolvedBackend;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut cfg = Config::default();
    cfg.cluster.block_records = 8192;
    cfg.fcm.max_iterations = 100;

    // Backend: PJRT artifacts when built, else native (with a notice).
    let backend: Arc<dyn KernelBackend> = Arc::new(ResolvedBackend::from_config(&cfg)?);
    println!("backend: {}", backend.name());
    if backend.name() == "native" {
        println!("  (artifacts/ not found — run `make artifacts` for the PJRT path)");
    }

    // Workload: SUSY-like at 60k records (18 features, 2 classes), the
    // paper's Table 3 configuration C=2, m=2.
    let dataset = builtin::susy(60_000, cfg.seed);
    let labels = dataset.labels.clone().unwrap();
    println!(
        "workload: {} — {} records x {} features",
        dataset.name,
        dataset.rows(),
        dataset.dims()
    );

    // Store on disk: real I/O through the block codec.
    let dir = std::env::temp_dir().join(format!("bigfcm_e2e_{}", std::process::id()));
    let store = Arc::new(BlockStore::on_disk(
        dataset.name.clone(),
        &dataset.features,
        cfg.cluster.block_records,
        cfg.cluster.workers,
        dir.clone(),
    )?);
    println!(
        "block store: {} blocks, {:.1} MiB on disk",
        store.num_blocks(),
        store.total_bytes() as f64 / (1024.0 * 1024.0)
    );

    let eps = 5.0e-7;

    // --- BigFCM (with fault injection to exercise re-execution) ---------
    let mut engine = Engine::new(
        EngineOptions {
            fault_rate: 0.1,
            fault_seed: 42,
            ..EngineOptions::from_cluster(&cfg.cluster)
        },
        cfg.overhead.clone(),
    );
    let big = BigFcm::new(cfg.clone())
        .backend(Arc::clone(&backend))
        .clusters(2)
        .fuzzifier(2.0)
        .epsilon(eps)
        .run_with_engine(&store, &mut engine)?;
    println!(
        "\nBigFCM: wall={:.2?}  modelled={:.0}s  (1 MR job, {} map tasks, {} attempts)",
        big.wall,
        big.modelled_s(),
        big.job.map_tasks,
        big.job.attempts
    );
    println!(
        "  streaming: locality hits {} / steals {}, prefetch hits {}",
        big.job.locality_hits, big.job.locality_steals, big.job.prefetch_hits
    );
    println!(
        "  driver: sample={} T_fcm={:.0?} T_wfcmpb={:.0?} -> flag={}",
        big.driver.sample_size,
        big.driver.t_fcm,
        big.driver.t_wfcmpb,
        if big.driver.flag_fcm { "FCM" } else { "WFCMPB" }
    );

    // --- Baselines on the same substrate --------------------------------
    let mut results = Vec::new();
    for algo in [BaselineAlgo::KMeans, BaselineAlgo::FuzzyKMeans] {
        let mut engine = Engine::new(
            EngineOptions::from_cluster(&cfg.cluster),
            cfg.overhead.clone(),
        );
        let mut bcfg = cfg.clone();
        bcfg.fcm.clusters = 2;
        bcfg.fcm.epsilon = eps;
        let run = run_baseline(algo, &bcfg, &store, Arc::clone(&backend), &mut engine)?;
        println!(
            "{}: wall={:.2?}  modelled={:.0}s  ({} MR jobs)",
            algo.as_str(),
            run.wall,
            run.modelled_s(),
            run.jobs
        );
        results.push(run);
    }

    // --- Headline metrics ------------------------------------------------
    println!("\n=== headline ===");
    for run in &results {
        println!(
            "speedup over {}: {:.1}x (modelled cluster time)",
            run.algo.as_str(),
            speedup(run.modelled_s(), big.modelled_s())
        );
    }
    let assign_big = assign_hard(&dataset.features, &big.centers);
    let assign_fkm = assign_hard(&dataset.features, &results[1].centers);
    let acc_big = confusion_accuracy(&assign_big, &labels, 2);
    let acc_fkm = confusion_accuracy(&assign_fkm, &labels, 2);
    println!(
        "accuracy: BigFCM {:.1}% vs Mahout FKM {:.1}% (overlapping classes: ~50% is the paper's own Table 7 number)",
        acc_big * 100.0,
        acc_fkm * 100.0
    );
    let mut rng = Pcg::new(7);
    let sil = silhouette_width_sampled(&dataset.features, &assign_big, 2000, &mut rng);
    println!("silhouette (2k sample): {sil:.4}");

    std::fs::remove_dir_all(dir).ok();
    Ok(())
}
