//! Multi-GiB scale harness (the paper's SUSY/HIGGS regime; EXPERIMENTS.md
//! §Streaming): generate a SUSY-like block store on disk *without ever
//! materializing the dataset*, stream it end-to-end through the full BigFCM
//! pipeline under a small byte-budgeted block cache with locality-aware
//! scheduling and prefetch on, then enforce the streaming envelopes:
//!
//! * **resident bytes** — `peak_resident_bytes ≤ budget + workers ×
//!   max_block_bytes` (the pipeline never holds more than the cache budget
//!   plus one in-flight block per worker);
//! * **mechanism liveness** — locality hits > 0 and prefetch hits > 0 (the
//!   scheduler honoured block placement and reads overlapped compute);
//! * **wall time** — optional `--max-wall-s` ceiling;
//! * **iteration residency** — an FCM convergence loop over the same
//!   store through an `IterativeSession` (sticky pruning slab, worker-side
//!   tree combine, startup charged once) must report `records_pruned > 0`
//!   after iteration 2.
//!
//! ```bash
//! # CI-sized (default): 1 GiB on disk, 64 MiB cache
//! cargo run --release --example scale_susy
//! # the paper's regime, locally:
//! cargo run --release --example scale_susy -- --bytes 2GiB --cache-mib 64
//! ```
//!
//! Exit status is non-zero when any envelope is violated, so the harness
//! can gate CI or local runs directly.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use bigfcm::config::{BoundModel, Config, FlagPolicy, QuantMode};
use bigfcm::coordinator::BigFcm;
use bigfcm::data::synth::susy_like;
use bigfcm::fcm::loops::{run_fcm_session, run_fcm_session_sharded, FcmParams, PruneConfig, SessionAlgo};
use bigfcm::fcm::{BlockBounds, BoundConfig, Kernel, KernelBackend, NativeBackend};
use bigfcm::hdfs::BlockStoreWriter;
use bigfcm::mapreduce::{
    Engine, EngineOptions, SessionOptions, ShardMergeMode, ShardedEngine, SlabState, MIB,
};

struct Args {
    /// Target on-disk store size in bytes.
    bytes: u64,
    /// Block-cache byte budget in MiB.
    cache_mib: u64,
    workers: usize,
    /// Records per block (65 536 × 18 f32 ≈ 4.5 MiB serialised).
    block_rows: usize,
    /// 0 disables the wall-time envelope.
    max_wall_s: f64,
    /// Iteration cap of the iteration-residency phase (0 skips it).
    session_iters: usize,
    /// Sticky-slab budget in MiB for the session phase (0 = auto-size to
    /// hold every block's pruning state).
    slab_mib: u64,
    /// Bound model of the session phase ("dmin" | "elkan").
    bounds: BoundModel,
    /// Quantized distance pre-pass of the session phase ("off" | "i8").
    quant: QuantMode,
    /// Engine shards of the sharded scale-out phase (≤ 1 skips it).
    shards: usize,
    /// Spill cold slab state to this disk ring instead of evicting it.
    spill_dir: Option<PathBuf>,
    /// Keep the generated store (for re-runs) instead of deleting it.
    keep: bool,
    dir: Option<PathBuf>,
    seed: u64,
}

impl Default for Args {
    fn default() -> Self {
        Self {
            bytes: 1 << 30, // 1 GiB
            cache_mib: 64,
            workers: 4,
            block_rows: 65_536,
            max_wall_s: 0.0,
            session_iters: 8,
            slab_mib: 0,
            bounds: BoundModel::Elkan,
            quant: QuantMode::Off,
            shards: 0,
            spill_dir: None,
            keep: false,
            dir: None,
            seed: 0xB16FC4,
        }
    }
}

/// Parse "2GiB", "512MiB", "64KiB" or a plain byte count (fractional unit
/// values like "1.5GiB" allowed).
fn parse_size(s: &str) -> Option<u64> {
    let lower = s.trim().to_ascii_lowercase();
    let (digits, mult) = if let Some(v) = lower.strip_suffix("gib") {
        (v, 1024.0 * 1024.0 * 1024.0)
    } else if let Some(v) = lower.strip_suffix("mib") {
        (v, 1024.0 * 1024.0)
    } else if let Some(v) = lower.strip_suffix("kib") {
        (v, 1024.0)
    } else {
        (lower.as_str(), 1.0)
    };
    let v: f64 = digits.trim().parse().ok()?;
    if !v.is_finite() || v < 0.0 {
        return None;
    }
    Some((v * mult) as u64)
}

fn usage() -> ! {
    eprintln!(
        "usage: scale_susy [--bytes SIZE] [--cache-mib N] [--workers N] \
         [--block-rows N] [--max-wall-s S] [--session-iters N] \
         [--slab-mib N] [--bounds dmin|elkan|hamerly] [--quant off|i8] \
         [--shards N] [--spill-dir PATH] [--dir PATH] [--keep] [--seed N]\n\
         SIZE accepts GiB/MiB/KiB suffixes, e.g. --bytes 2GiB; \
         --slab-mib 0 auto-sizes the pruning slab to the store and the \
         bound model; --spill-dir rides out undersized slabs on disk"
    );
    std::process::exit(2);
}

fn parse_args() -> Args {
    let mut args = Args::default();
    let mut it = std::env::args().skip(1);
    while let Some(flag) = it.next() {
        let mut val = |name: &str| -> String {
            it.next().unwrap_or_else(|| {
                eprintln!("missing value for {name}");
                usage()
            })
        };
        match flag.as_str() {
            "--bytes" => {
                args.bytes = parse_size(&val("--bytes")).unwrap_or_else(|| usage());
            }
            "--cache-mib" => {
                args.cache_mib = val("--cache-mib").parse().unwrap_or_else(|_| usage());
            }
            "--workers" => {
                args.workers = val("--workers").parse().unwrap_or_else(|_| usage());
            }
            "--block-rows" => {
                args.block_rows = val("--block-rows").parse().unwrap_or_else(|_| usage());
            }
            "--max-wall-s" => {
                args.max_wall_s = val("--max-wall-s").parse().unwrap_or_else(|_| usage());
            }
            "--session-iters" => {
                args.session_iters = val("--session-iters").parse().unwrap_or_else(|_| usage());
            }
            "--slab-mib" => {
                args.slab_mib = val("--slab-mib").parse().unwrap_or_else(|_| usage());
            }
            "--bounds" => {
                args.bounds = BoundModel::parse(&val("--bounds")).unwrap_or_else(|_| usage());
            }
            "--quant" => {
                args.quant = QuantMode::parse(&val("--quant")).unwrap_or_else(|_| usage());
            }
            "--shards" => {
                args.shards = val("--shards").parse().unwrap_or_else(|_| usage());
            }
            "--spill-dir" => args.spill_dir = Some(PathBuf::from(val("--spill-dir"))),
            "--dir" => args.dir = Some(PathBuf::from(val("--dir"))),
            "--keep" => args.keep = true,
            "--seed" => args.seed = val("--seed").parse().unwrap_or_else(|_| usage()),
            _ => usage(),
        }
    }
    if args.bytes == 0 || args.block_rows == 0 || args.workers == 0 {
        usage();
    }
    if args.shards > args.workers {
        eprintln!("--shards {} > --workers {}: every shard needs a worker", args.shards, args.workers);
        usage();
    }
    args
}

fn mib(b: u64) -> f64 {
    b as f64 / MIB as f64
}

/// In-harness regression check: run one refreshed pruned pass over a
/// synthetic 512-record block under the exact `(bounds, quant)` pair the
/// harness will use, then compare the sizer's `per_record` formula against
/// the bytes `BlockBounds` actually accounts. Fails fast — before the
/// multi-GiB run — if the layout ever grows a term the formula misses.
fn assert_sizer_covers(
    bounds: BoundModel,
    quant: QuantMode,
    clusters: usize,
    dims: usize,
    per_record: u64,
) {
    let n = 512usize;
    let x = susy_like(n, 0xB16F).features;
    let v = x.slice_rows(0, clusters);
    let w = vec![1.0f32; n];
    let mut st = BlockBounds::default();
    let cfg = BoundConfig { model: bounds, tolerance: 5e-3, refresh_every: 4, quant };
    NativeBackend
        .pruned_partials(Kernel::FcmFast, &x, &v, &w, 2.0, &mut st, &cfg)
        .expect("sizer probe pass");
    let actual = st.slab_bytes();
    let budget = per_record * n as u64 + 4096;
    assert!(
        budget >= actual,
        "slab auto-sizer undercharges: formula {} B < accounted {} B \
         (bounds {}, quant {}, C={}, d={})",
        budget,
        actual,
        bounds.as_str(),
        quant.as_str(),
        clusters,
        dims
    );
}

/// Deletes the generated store on every exit path (success, error or
/// panic) when armed. Never armed for `--keep` runs or user-supplied
/// `--dir` paths — a pre-existing directory the user named may hold
/// unrelated files and is never deleted by this harness.
struct Cleanup(Option<PathBuf>);

impl Drop for Cleanup {
    fn drop(&mut self) {
        if let Some(dir) = self.0.take() {
            std::fs::remove_dir_all(dir).ok();
        }
    }
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = parse_args();
    let dims = 18usize; // SUSY feature count
    let row_bytes = (dims * 4) as u64;
    let block_bytes_est = args.block_rows as u64 * row_bytes + 24;
    let n_blocks = (((args.bytes + block_bytes_est - 1) / block_bytes_est).max(1)) as usize;

    let user_dir = args.dir.is_some();
    let dir = args.dir.clone().unwrap_or_else(|| {
        std::env::temp_dir().join(format!("bigfcm_scale_{}", std::process::id()))
    });
    // Armed for the default temp-dir case only; disarmed by --keep, and
    // user-supplied --dir paths are never deleted.
    let cleanup = Cleanup((!args.keep && !user_dir).then(|| dir.clone()));

    // ---- Phase 0: stream the store to disk, one block at a time --------
    println!(
        "generating SUSY-like store: {} blocks x {} rows ({:.0} MiB target) -> {}",
        n_blocks,
        args.block_rows,
        mib(args.bytes),
        dir.display()
    );
    let t0 = Instant::now();
    let mut writer = BlockStoreWriter::create("SUSY-like", dims, args.workers, dir.clone())?;
    for b in 0..n_blocks {
        let block = susy_like(args.block_rows, args.seed.wrapping_add(b as u64));
        writer.append(&block.features)?;
        if (b + 1) % 50 == 0 || b + 1 == n_blocks {
            println!(
                "  wrote {}/{} blocks ({:.0} MiB, {:.1}s)",
                b + 1,
                n_blocks,
                mib(writer.total_bytes()),
                t0.elapsed().as_secs_f64()
            );
        }
    }
    let store = Arc::new(writer.finish()?);
    println!(
        "store ready: {} rows, {:.0} MiB on disk, max block {:.2} MiB ({:.1}s)",
        store.total_rows(),
        mib(store.total_bytes()),
        mib(store.max_block_bytes()),
        t0.elapsed().as_secs_f64()
    );

    // ---- Phase 1+2: full pipeline under the byte budget ----------------
    let mut cfg = Config::default();
    cfg.seed = args.seed;
    cfg.cluster.workers = args.workers;
    cfg.cluster.cache_mib = args.cache_mib as usize;
    cfg.fcm.clusters = 2; // SUSY: signal vs background
    cfg.fcm.max_iterations = 100;
    // Pin the driver race so repeated harness runs are comparable.
    cfg.fcm.flag_policy = FlagPolicy::ForceFcm;

    let budget = args.cache_mib * MIB;
    let mut engine = Engine::new(EngineOptions::from_cluster(&cfg.cluster), cfg.overhead.clone());
    let t1 = Instant::now();
    // Errors may `?` straight out: `cleanup` removes the store on every
    // exit path, including generation-phase failures above.
    let run = BigFcm::new(cfg.clone())
        .clusters(2)
        .run_with_engine(&store, &mut engine)?;
    let wall_s = t1.elapsed().as_secs_f64();

    let max_block = store.max_block_bytes();
    let envelope = budget + args.workers as u64 * max_block;
    // Snapshot the pipeline phase's cache outcome before the session phase
    // borrows the engine mutably (session iterations reset the per-job
    // peak meters as part of their residency contract).
    let pipeline_peak = engine.block_cache().peak_resident_bytes();
    println!("\n=== scale_susy results ===");
    println!(
        "pipeline wall {wall_s:.1}s  ({:.1} MiB/s through FCM), modelled cluster {:.0}s",
        mib(store.total_bytes()) / wall_s,
        run.modelled_s()
    );
    println!(
        "map tasks {}: locality hits {}, steals {}, prefetch hits {}",
        run.job.map_tasks, run.job.locality_hits, run.job.locality_steals, run.job.prefetch_hits
    );
    println!(
        "cache: budget {:.0} MiB, peak resident {:.1} MiB (envelope {:.1} MiB), \
         hits {} misses {} prefetches {}",
        mib(budget),
        mib(pipeline_peak),
        mib(envelope),
        engine.block_cache().hits(),
        engine.block_cache().misses(),
        engine.block_cache().prefetches()
    );

    // ---- Phase 3: iteration-residency (sticky slab + pruned passes) ----
    // An FCM convergence loop over the same store through an
    // IterativeSession, warm-started from the pipeline's centers: the
    // first pass refreshes the slab bounds, later passes serve bounded
    // records from the slab and tree-combine partials on the workers.
    let mut session_run = None;
    if args.session_iters > 0 {
        println!(
            "\n=== iteration-residency phase (≤ {} iterations) ===",
            args.session_iters
        );
        let params = FcmParams {
            epsilon: 1e-12, // run the full budget of iterations
            max_iterations: args.session_iters,
            ..Default::default()
        };
        let backend: Arc<dyn KernelBackend> = Arc::new(NativeBackend);
        // Full pruning coverage needs every block's state resident. The
        // sizing rule is per bound model — the elkan layout stores an
        // extra per-record × per-center lower-bound row the accounting
        // charges (the old flat-8-B/record assumption undersized it by
        // C·4 B/record and the auto-sized slab thrashed):
        //   dmin : ≈ 4·(C+2)  B/record (u^m rows + d_min + obj)
        //   elkan: ≈ 4·(2C+2) B/record (u^m rows + lb rows + obj)
        // plus a small per-block constant — far below the slab budget at
        // CI scale, but a 1 GiB store needs a few hundred MiB. The
        // harness's job is to demonstrate the mechanism, so it auto-sizes
        // (with 25% headroom) unless --slab-mib pins the budget; an
        // undersized slab degrades to exact recomputes (slab_evictions) —
        // or, with --spill-dir, rides the disk ring (slab_spilled_bytes /
        // slab_reloads) at unchanged results.
        let mut prune = PruneConfig::from_cluster(&cfg.cluster);
        prune.bounds = args.bounds;
        prune.quant = args.quant;
        prune.spill_dir = args.spill_dir.clone();
        let mut per_record = match args.bounds {
            BoundModel::DMin => 4 * (cfg.fcm.clusters as u64 + 2),
            BoundModel::Elkan => 4 * (2 * cfg.fcm.clusters as u64 + 2),
            // Elkan's layout plus the per-record single fast bound.
            BoundModel::Hamerly => 4 * (2 * cfg.fcm.clusters as u64 + 3),
        };
        if args.quant.enabled() {
            // The certified pre-pass widens every model to the lb-carrying
            // layout (dmin otherwise has none) and adds the i8 sidecar
            // codes (1 B × d per record; scales ride the block constant).
            if matches!(args.bounds, BoundModel::DMin) {
                per_record += 4 * cfg.fcm.clusters as u64;
            }
            per_record += dims as u64;
        }
        let per_block = args.block_rows as u64 * per_record + 4096;
        // Regression guard: the formula above must cover the real
        // accounted layout, otherwise auto-sized slabs thrash (exactly
        // how the missing hamerly term slipped through before: the
        // 4·(2C+2) elkan formula didn't charge hamerly's extra fast-bound
        // scalar, hamerly runs undersized the slab and evicted on every
        // pass). Measured against BlockBounds' own byte accounting on a
        // synthetic block, so the layout and the sizer cannot drift apart
        // silently again.
        assert_sizer_covers(args.bounds, args.quant, cfg.fcm.clusters, dims, per_record);
        if args.slab_mib > 0 {
            prune.slab_bytes = args.slab_mib * MIB;
        } else {
            let auto = per_block * n_blocks as u64 * 5 / 4;
            prune.slab_bytes = prune.slab_bytes.max(auto);
        }
        println!(
            "slab budget {:.0} MiB ({} blocks × ≈{:.2} MiB {} pruning state, quant {})",
            mib(prune.slab_bytes),
            n_blocks,
            mib(per_block),
            args.bounds.as_str(),
            args.quant.as_str()
        );
        let t2 = Instant::now();
        let srun = run_fcm_session(
            &mut engine,
            &store,
            backend,
            SessionAlgo::Fcm,
            run.centers.clone(),
            &params,
            &prune,
            SessionOptions::default(),
            None,
        )?;
        let session_wall = t2.elapsed().as_secs_f64();
        for (i, s) in srun.per_iteration.iter().enumerate() {
            println!(
                "  iter {:>2}: pruned {:>9} records ({:>8} via quant), reduce parts {:>2} \
                 (depth {}), reduce wall {:.3} ms, slab {:.1} MiB ({} evictions, \
                 {:.1} MiB spilled, {} reloads)",
                i + 1,
                s.records_pruned,
                s.records_pruned_quant,
                s.reduce_parts,
                s.combine_depth,
                s.reduce_wall_s * 1e3,
                mib(s.slab_bytes),
                s.slab_evictions,
                mib(s.slab_spilled_bytes),
                s.slab_reloads
            );
        }
        println!(
            "session: {} iterations in {session_wall:.1}s wall ({:.1} MiB/s·iter), \
             {} records pruned total ({} via quant, sidecar peak {:.1} MiB, \
             built in {:.2}s), startup charged once: {}",
            srun.jobs,
            mib(store.total_bytes()) * srun.jobs as f64 / session_wall.max(1e-9),
            srun.records_pruned,
            srun.records_pruned_quant,
            mib(srun.quant_sidecar_bytes),
            srun.quant_build_s,
            (srun.sim.job_startup_s - cfg.overhead.job_startup_s).abs() < 1e-9
        );
        session_run = Some(srun);
    }

    // ---- Phase 4: sharded scale-out (per-shard residency envelopes) ----
    // The same convergence loop across N engine shards with the exact
    // two-level merge: each shard runs its slice of the store under its
    // slice of the cache budget, and the envelope the single-engine phases
    // enforce must hold **per shard** — peak resident ≤ the shard's cache
    // slice plus one in-flight block per shard worker.
    let mut shard_failures: Vec<String> = Vec::new();
    if args.shards > 1 {
        println!("\n=== sharded phase ({} shards, exact merge) ===", args.shards);
        cfg.cluster.shards = args.shards;
        let mut sh_engine = ShardedEngine::new(
            &store,
            &EngineOptions::from_cluster(&cfg.cluster),
            cfg.overhead.clone(),
            args.shards,
            cfg.shard.steal_penalty,
        );
        let params = FcmParams {
            epsilon: 1e-12,
            max_iterations: args.session_iters.max(2),
            ..Default::default()
        };
        let backend: Arc<dyn KernelBackend> = Arc::new(NativeBackend);
        let mut prune = PruneConfig::from_cluster(&cfg.cluster);
        prune.bounds = args.bounds;
        prune.quant = args.quant;
        let t3 = Instant::now();
        let srun = run_fcm_session_sharded(
            &mut sh_engine,
            &store,
            backend,
            SessionAlgo::Fcm,
            run.centers.clone(),
            &params,
            &prune,
            SessionOptions::default(),
            None,
            ShardMergeMode::Exact,
        )?;
        let sharded_wall = t3.elapsed().as_secs_f64();
        println!(
            "sharded: {} iterations in {sharded_wall:.1}s wall, steals {} ({:.2} MiB), \
             modelled {:.0}s",
            srun.run.result.iterations,
            srun.shard_steals,
            mib(srun.shard_steal_bytes),
            srun.run.sim.total_s(),
        );
        for (i, slice) in sh_engine.plan().slices.iter().enumerate() {
            let peak = srun.per_shard_peak_resident_bytes[i];
            let shard_envelope = slice.cache_bytes + slice.workers as u64 * max_block;
            println!(
                "  shard {i}: blocks {:>4} (stolen {:>3}), workers {}, cache {:.1} MiB, \
                 peak {:.1} MiB (envelope {:.1} MiB), pruned {}",
                slice.block_ids.len(),
                slice.stolen.len(),
                slice.workers,
                mib(slice.cache_bytes),
                mib(peak),
                mib(shard_envelope),
                srun.records_pruned_per_shard[i],
            );
            if peak > shard_envelope {
                shard_failures.push(format!(
                    "shard {i} resident-byte envelope violated: peak {} > cache slice {} + \
                     {} workers x {}",
                    peak, slice.cache_bytes, slice.workers, max_block
                ));
            }
        }
    }

    let mut failures = Vec::new();
    failures.extend(shard_failures);
    if let Some(srun) = &session_run {
        if args.session_iters >= 3 {
            let pruned_after_two: u64 = srun
                .per_iteration
                .iter()
                .skip(2)
                .map(|s| s.records_pruned)
                .sum();
            if pruned_after_two == 0 {
                failures.push(
                    "iteration-residency: no records pruned after iteration 2".to_string(),
                );
            }
        }
        if (srun.sim.job_startup_s - cfg.overhead.job_startup_s).abs() > 1e-9 {
            failures.push(format!(
                "iteration-residency: resident loop charged startup {:.1}s (expected one {:.1}s charge)",
                srun.sim.job_startup_s, cfg.overhead.job_startup_s
            ));
        }
    }
    // Both phases must respect the residency envelope: the pipeline's
    // snapshot and the max over every session iteration's peak (the
    // session resets the per-job meters between iterations, so the
    // loop-wide figure comes from the run result, not a post-loop gauge).
    let session_peak = session_run
        .as_ref()
        .map(|s| s.peak_resident_bytes)
        .unwrap_or(0);
    if pipeline_peak.max(session_peak) > envelope {
        failures.push(format!(
            "resident-byte envelope violated: peak {} > budget {} + {} workers x {}",
            pipeline_peak.max(session_peak),
            budget,
            args.workers,
            max_block
        ));
    }
    if run.job.locality_hits == 0 {
        failures.push("no locality hits: scheduler ignored block placement".into());
    }
    if run.job.prefetch_hits == 0 {
        failures.push("no prefetch hits: reads never overlapped compute".into());
    }
    if args.max_wall_s > 0.0 && wall_s > args.max_wall_s {
        failures.push(format!("wall {wall_s:.1}s > envelope {:.1}s", args.max_wall_s));
    }

    if cleanup.0.is_none() {
        println!("kept store at {}", dir.display());
    }

    // Exit via `Err`, not `process::exit` — the cleanup guard must drop.
    if failures.is_empty() {
        println!("scale_susy: all envelopes OK");
        Ok(())
    } else {
        for f in &failures {
            eprintln!("FAIL: {f}");
        }
        Err(format!("{} envelope violation(s)", failures.len()).into())
    }
}
