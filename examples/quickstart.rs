//! Quickstart: cluster the real Iris dataset with BigFCM in ~20 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use bigfcm::config::Config;
use bigfcm::coordinator::BigFcm;
use bigfcm::data::builtin::iris;
use bigfcm::fcm::assign_hard;
use bigfcm::metrics::confusion_accuracy;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dataset = iris();
    println!("Iris: {} records x {} features", dataset.rows(), dataset.dims());

    // Paper parameters for Iris (Table 6): C=3, m=1.2, eps=5e-2.
    let mut cfg = Config::default();
    cfg.cluster.block_records = 64; // several blocks even on 150 records
    let run = BigFcm::new(cfg)
        .clusters(3)
        .fuzzifier(1.2)
        .epsilon(5.0e-2)
        .run_dataset(&dataset)?;

    println!(
        "driver: sample={} flag={} | job: {} map tasks | wall={:?}",
        run.driver.sample_size,
        if run.driver.flag_fcm { "FCM" } else { "WFCMPB" },
        run.job.map_tasks,
        run.wall,
    );
    for i in 0..run.centers.rows() {
        println!(
            "center[{i}]  weight={:6.1}  {:?}",
            run.weights[i],
            run.centers.row(i)
        );
    }

    let labels = dataset.labels.as_ref().unwrap();
    let assignments = assign_hard(&dataset.features, &run.centers);
    let acc = confusion_accuracy(&assignments, labels, 3);
    println!("confusion accuracy: {:.1}% (paper reports 92.0%)", acc * 100.0);
    Ok(())
}
