//! End-to-end serving walkthrough: train → persist a model bundle →
//! answer concurrent online membership queries → bulk-label a store.
//!
//! ```bash
//! cargo run --release --example online_serving
//! ```
//!
//! Mirrors the production shape: the training half runs the
//! iteration-resident session loop; the serving half never touches the
//! training data again — everything it needs travels through the
//! checksummed `ModelBundle` file, exactly what `bigfcm run --save-model`
//! writes and `bigfcm serve-bench` / `bigfcm score` load.

use std::sync::Arc;

use bigfcm::config::OverheadConfig;
use bigfcm::data::normalize::Scaler;
use bigfcm::data::synth::blobs;
use bigfcm::fcm::loops::{run_fcm_session, FcmParams, PruneConfig, SessionAlgo, Variant};
use bigfcm::fcm::{KernelBackend, NativeBackend};
use bigfcm::hdfs::BlockStore;
use bigfcm::mapreduce::{Engine, EngineOptions, SessionOptions};
use bigfcm::serve::{dense_from_top_k, run_score_job, ModelBundle, ScoreService, ServeOptions};

fn main() {
    let tmp = std::env::temp_dir().join(format!("bigfcm_serving_{}", std::process::id()));
    std::fs::remove_dir_all(&tmp).ok();
    std::fs::create_dir_all(&tmp).expect("create scratch dir");

    // ---- Train (the first half of the two-phase shape) -----------------
    let data = blobs(8_192, 6, 4, 0.25, 42);
    let scaler = Scaler::min_max(&data.features);
    let mut normalized = data.features.clone();
    scaler.apply(&mut normalized);
    let store = Arc::new(BlockStore::in_memory("blobs", &normalized, 1_024, 4).unwrap());
    let backend: Arc<dyn KernelBackend> = Arc::new(NativeBackend);
    let mut engine = Engine::new(EngineOptions::default(), OverheadConfig::default());
    let mut rng = bigfcm::prng::Pcg::new(43);
    let v0 = bigfcm::fcm::seeding::random_records(&normalized, 4, &mut rng);
    let params = FcmParams { epsilon: 1e-9, max_iterations: 60, ..Default::default() };
    let run = run_fcm_session(
        &mut engine,
        &store,
        Arc::clone(&backend),
        SessionAlgo::Fcm,
        v0,
        &params,
        &PruneConfig::default(),
        SessionOptions::default(),
        None,
    )
    .expect("training session");
    println!(
        "trained: {} iterations, converged={}, records_pruned={}",
        run.result.iterations, run.result.converged, run.records_pruned
    );

    // ---- Persist + reload the bundle ----------------------------------
    let mut bundle =
        ModelBundle::new(run.result.centers.clone(), SessionAlgo::Fcm, Variant::Fast, params.m);
    bundle.weights = run.result.weights.clone();
    bundle.scaler = Some(scaler);
    bundle.dataset = "blobs".into();
    bundle.seed = 42;
    bundle.trained_rows = data.features.rows() as u64;
    bundle.iterations = run.result.iterations as u64;
    bundle.objective = run.result.objective;
    bundle.converged = run.result.converged;
    bundle.records_pruned = run.records_pruned;
    let model_path = tmp.join("model.bfm");
    let bytes = bundle.save(&model_path).expect("save bundle");
    let reloaded = ModelBundle::load(&model_path).expect("load bundle");
    assert_eq!(reloaded.encode(), bundle.encode(), "bundle roundtrip must be bitwise");
    println!("bundle: {} B at {}\n{}", bytes, model_path.display(), reloaded.summary());

    // ---- Online service: concurrent clients, micro-batched scoring ----
    let service = Arc::new(
        ScoreService::builder(reloaded.clone())
            .options(ServeOptions {
                linger: std::time::Duration::from_millis(2),
                ..Default::default()
            })
            .spawn(Arc::clone(&backend))
            .expect("score service"),
    );
    let raw = Arc::new(data.features.clone());
    let clients = 4usize;
    let per_client = 200usize;
    let handles: Vec<_> = (0..clients)
        .map(|ci| {
            let svc = Arc::clone(&service);
            let x = Arc::clone(&raw);
            std::thread::spawn(move || {
                for r in 0..per_client {
                    let u = svc.score(x.row((ci * 2048 + r) % x.rows())).expect("score");
                    let s: f32 = u.iter().sum();
                    assert!((s - 1.0).abs() < 1e-5, "membership row sums to {s}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread");
    }
    let stats = service.stats();
    println!(
        "online: {} requests over {} batches (fill {:.2}), p50 {} us / p95 {} us / p99 {} us",
        stats.requests, stats.batches, stats.batch_fill, stats.p50_us, stats.p95_us, stats.p99_us
    );
    assert!(stats.batch_fill > 1.0, "concurrent clients should coalesce");

    // ---- Hot reload: swap the bundle without dropping the service -----
    let before = service.generation();
    let after = service.reload(reloaded.clone()).expect("hot reload");
    assert_eq!(after, before + 1, "reload bumps the generation");
    let stamped = service.score_stamped(data.features.row(0)).expect("post-reload score");
    assert_eq!(stamped.generation, after, "responses carry the generation they scored under");
    println!("hot reload: generation {before} -> {after}");

    // ---- Bulk ScoreJob: label the whole store -------------------------
    let raw_store = Arc::new(BlockStore::in_memory("blobs-raw", &data.features, 1_024, 4).unwrap());
    let out_dir = tmp.join("memberships");
    let outcome = run_score_job(
        &mut engine,
        &raw_store,
        Arc::new(reloaded),
        backend,
        2,
        bigfcm::config::QuantMode::Off,
        out_dir.clone(),
    )
    .expect("bulk score job");
    println!(
        "bulk: labeled {} records into {} blocks at {} (mean top-1 {:.3})",
        outcome.totals.rows,
        outcome.store.num_blocks(),
        out_dir.display(),
        outcome.totals.top1_mass / outcome.totals.rows as f64,
    );
    // Spot-check one labeled row against the online service.
    let first = outcome.store.read_block(0).expect("read membership block");
    let dense = dense_from_top_k(first.row(0), 4);
    let online = service.score(data.features.row(0)).expect("online row 0");
    let top = online
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    assert!((dense[top] - online[top]).abs() < 1e-6, "bulk and online disagree");
    println!("bulk row 0 agrees with the online service on the top membership");

    std::fs::remove_dir_all(&tmp).ok();
}
