//! Network-intrusion clustering — the KDD99 scenario of the paper's
//! evaluation (Tables 6–7: C=23, m=1.2) and of its motivating applications
//! (§2: "a recent application of FCM for network intrusion detection").
//!
//! Clusters a KDD99-like trace (41 features, 23 imbalanced attack classes),
//! then uses the fitted centers as a detector: records far from every
//! center are flagged anomalous.
//!
//! ```bash
//! cargo run --release --example intrusion_detection
//! ```

use bigfcm::config::Config;
use bigfcm::coordinator::BigFcm;
use bigfcm::data::builtin;
use bigfcm::data::normalize::Scaler;
use bigfcm::fcm::assign_hard;
use bigfcm::metrics::confusion_accuracy;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = Config::default();

    // KDD99-like: 50k records, 41 features, 23 classes with the original's
    // smurf/neptune/normal dominance.
    let mut dataset = builtin::kdd99(50_000, cfg.seed);
    let labels = dataset.labels.clone().unwrap();
    println!(
        "workload: {} — {} records x {} features, {} classes",
        dataset.name,
        dataset.rows(),
        dataset.dims(),
        dataset.n_classes
    );

    // The paper normalises KDD99 before clustering (§4.1).
    let scaler = Scaler::min_max(&dataset.features);
    scaler.apply(&mut dataset.features);

    // Paper parameters (Table 6): C=23, m=1.2, eps=5e-7.
    let run = BigFcm::new(cfg)
        .clusters(23)
        .fuzzifier(1.2)
        .epsilon(5.0e-7)
        .run_dataset(&dataset)?;
    println!(
        "clustered in wall={:.2?} (modelled {:.0}s cluster time, 1 MR job)",
        run.wall,
        run.modelled_s()
    );

    let assignments = assign_hard(&dataset.features, &run.centers);
    let acc = confusion_accuracy(&assignments, &labels, 23);
    println!("confusion accuracy: {:.1}% (paper reports 82.0%)", acc * 100.0);

    // Simple detector: distance to the nearest center, thresholded at the
    // 99th percentile — records beyond it are "anomalous".
    let mut dists: Vec<f64> = (0..dataset.rows())
        .map(|i| {
            (0..23)
                .map(|c| dataset.features.row_dist2(i, run.centers.row(c)))
                .fold(f64::INFINITY, f64::min)
        })
        .collect();
    let mut sorted = dists.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let threshold = sorted[(sorted.len() as f64 * 0.99) as usize];
    let flagged = dists.iter().filter(|&&d| d > threshold).count();

    // How many of the flagged records belong to rare attack classes
    // (labels >= 3 are the 20 rare attacks in our generator)?
    let rare_flagged = (0..dataset.rows())
        .filter(|&i| dists[i] > threshold && labels[i] >= 3)
        .count();
    println!(
        "detector: {} records flagged beyond p99 distance; {:.0}% of them are rare-class traffic",
        flagged,
        100.0 * rare_flagged as f64 / flagged.max(1) as f64
    );
    dists.clear();
    Ok(())
}
