#!/usr/bin/env bash
# Tier-1 verification: format, release build, full test suite.
# Run from anywhere; operates on the rust/ crate.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "rustfmt unavailable in this toolchain; skipping format check"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "verify: OK"
