#!/usr/bin/env bash
# Tier-1 verification: format, release build, full test suite.
# Run from anywhere; operates on the rust/ crate.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
# Advisory for now: the seed predates format enforcement and was authored
# where rustfmt is unavailable, so drift is reported loudly but does not
# fail the gate. Flip to hard (drop the `|| true`) after running
# `cargo fmt` once on a machine with the toolchain and committing it.
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check || echo "WARNING: rustfmt drift above (advisory until the tree is formatted once)"
else
    echo "rustfmt unavailable in this toolchain; skipping format check"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "verify: OK"
