#!/usr/bin/env bash
# Tier-1 verification: format, release build, full test suite.
# Run from anywhere; operates on the rust/ crate.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
# Advisory for now: the seed predates format enforcement and was authored
# where rustfmt is unavailable, so drift is reported loudly but does not
# fail the gate. Flip to hard (drop the `|| true`) after running
# `cargo fmt` once on a machine with the toolchain and committing it.
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check || echo "WARNING: rustfmt drift above (advisory until the tree is formatted once)"
else
    echo "rustfmt unavailable in this toolchain; skipping format check"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== serve smoke (bigfcm serve-bench) =="
# The serving-layer acceptance in miniature: 2+ concurrent closed-loop
# clients must coalesce into micro-batches (batch fill > 1) and the p50/
# p95/p99 report must come out. A generous linger keeps this robust on
# loaded CI runners; --require-coalescing makes fill <= 1 a hard failure.
cargo run --release --bin bigfcm -- serve-bench \
    --clients 2 --records 200 --dataset-records 4096 --clusters 3 \
    --max-batch 32 --linger-us 2000 --json none --require-coalescing

echo "== score smoke (bigfcm score --quant i8) =="
# Bulk-scoring acceptance in miniature: train a tiny session model, then
# label the store through the quantized candidate pre-pass (approximate
# distances select candidates, exact math scores only those). Exercises
# the sidecar build, the top-k gather and the JobStats quant counters
# end-to-end on the release binary.
SMOKE_DIR="$(mktemp -d)"
trap 'rm -rf "$SMOKE_DIR"' EXIT
# C=6 with top-k 2 keeps 2k < C, so the candidate pre-pass actually
# engages (it falls back to exact scoring when 2k >= C).
cargo run --release --bin bigfcm -- session \
    --dataset susy --records 4096 --clusters 6 --iters 5 \
    --save-model "$SMOKE_DIR/smoke.bfm"
cargo run --release --bin bigfcm -- score \
    --dataset susy --records 4096 --topk 2 --quant i8 \
    --model "$SMOKE_DIR/smoke.bfm" --out "$SMOKE_DIR/scored"

echo "== chaos smoke (deterministic fault injection + recovery) =="
# One transient read fault tripped at the first demand block read: the
# session must run to completion while reporting exactly one recovered
# retry (and no aborts) on the recovery counter line. Same seed, same
# schedule — this is replayable, not statistical.
CHAOS_OUT="$(cargo run --release --bin bigfcm -- session \
    --dataset susy --records 4096 --clusters 3 --iters 4 \
    --set faults.seed=11 --set faults.trip_site=block_read --set faults.trip_at=0)"
echo "$CHAOS_OUT" | grep -q "recovery: read retries 1, read aborts 0" \
    || { echo "chaos smoke: expected one recovered read retry"; echo "$CHAOS_OUT"; exit 1; }
echo "chaos smoke: one injected read fault recovered transparently"

echo "== checkpoint/resume smoke =="
cargo run --release --bin bigfcm -- session \
    --dataset susy --records 4096 --clusters 3 --iters 4 \
    --checkpoint "$SMOKE_DIR/session.ckpt" --checkpoint-every 2
[ -s "$SMOKE_DIR/session.ckpt" ] || { echo "checkpoint file was not written"; exit 1; }
RESUME_OUT="$(cargo run --release --bin bigfcm -- session \
    --dataset susy --records 4096 --clusters 3 --iters 4 \
    --resume "$SMOKE_DIR/session.ckpt")"
echo "$RESUME_OUT" | grep -q "resuming from" \
    || { echo "resume smoke: session did not warm-start"; echo "$RESUME_OUT"; exit 1; }
RESCUE_OUT="$(cargo run --release --bin bigfcm -- session \
    --dataset susy --records 2048 --clusters 3 --iters 2 \
    --resume-or-cold "$SMOKE_DIR/does-not-exist.ckpt")"
echo "$RESCUE_OUT" | grep -q "cold-starting" \
    || { echo "resume-or-cold smoke: missing cold-start fallback"; echo "$RESCUE_OUT"; exit 1; }
echo "checkpoint smoke: write, warm-start resume and cold-start fallback all OK"

echo "== sharded smoke (exact bitwise drop-in + representative delta) =="
# The scale-out tentpole on the release binary: `--shards 2 --merge exact`
# must reproduce the single-engine centers bit for bit (the CLI prints an
# fnv1a fingerprint of the final centers for exactly this diff), and the
# representative exchange must report its measured objective delta.
ONE_OUT="$(cargo run --release --bin bigfcm -- session \
    --dataset susy --records 4096 --clusters 3 --iters 4 --shards 1)"
TWO_OUT="$(cargo run --release --bin bigfcm -- session \
    --dataset susy --records 4096 --clusters 3 --iters 4 --shards 2 --merge exact)"
ONE_FP="$(echo "$ONE_OUT" | grep "centers fnv1a=")"
TWO_FP="$(echo "$TWO_OUT" | grep "centers fnv1a=")"
[ -n "$ONE_FP" ] || { echo "sharded smoke: no centers fingerprint printed"; echo "$ONE_OUT"; exit 1; }
[ "$ONE_FP" = "$TWO_FP" ] || {
    echo "sharded smoke: exact merge is not bitwise ($ONE_FP vs $TWO_FP)"; exit 1; }
echo "$TWO_OUT" | grep -q "sharded: 2 shards, merge=exact" \
    || { echo "sharded smoke: missing per-shard summary"; echo "$TWO_OUT"; exit 1; }
REP_OUT="$(cargo run --release --bin bigfcm -- session \
    --dataset susy --records 4096 --clusters 3 --iters 4 \
    --shards 2 --merge representative)"
echo "$REP_OUT" | grep -q "merge objective delta: last" \
    || { echo "sharded smoke: representative merge reported no objective delta"; echo "$REP_OUT"; exit 1; }
echo "sharded smoke: exact merge bitwise, representative delta reported"

echo "== trace smoke (session --trace-out + --timeline) =="
# Observability acceptance in miniature: a sharded session with tracing on
# must emit a parseable Chrome-trace JSON with the span taxonomy present,
# and print the per-iteration --timeline table.
TRACE_OUT="$(cargo run --release --bin bigfcm -- session \
    --dataset susy --records 4096 --clusters 3 --iters 4 --shards 2 \
    --trace-out "$SMOKE_DIR/trace.json" --timeline)"
echo "$TRACE_OUT" | grep -q "timeline:" \
    || { echo "trace smoke: --timeline printed no table"; echo "$TRACE_OUT"; exit 1; }
echo "$TRACE_OUT" | grep -q "trace: wrote" \
    || { echo "trace smoke: no trace emission line"; echo "$TRACE_OUT"; exit 1; }
[ -s "$SMOKE_DIR/trace.json" ] || { echo "trace smoke: trace.json missing/empty"; exit 1; }
# Keep a copy outside the mktemp dir (removed on exit) so CI can upload the
# trace as an artifact; target/ is already gitignored.
cp "$SMOKE_DIR/trace.json" target/trace_smoke.json
if command -v python3 >/dev/null 2>&1; then
    python3 - "$SMOKE_DIR/trace.json" <<'PYEOF'
import json, sys
doc = json.load(open(sys.argv[1]))
events = doc["traceEvents"]
names = {e.get("name") for e in events if e.get("ph") == "X"}
for want in ("session", "iteration", "shard", "job", "map_task"):
    assert want in names, f"span {want!r} missing from trace (have {sorted(names)})"
assert all(e.get("dur", 0) >= 0 for e in events if e.get("ph") == "X"), "negative duration"
print(f"trace smoke: {len(events)} events, taxonomy present")
PYEOF
else
    grep -q '"traceEvents"' "$SMOKE_DIR/trace.json" \
        || { echo "trace smoke: not a Chrome trace document"; exit 1; }
    grep -q '"map_task"' "$SMOKE_DIR/trace.json" \
        || { echo "trace smoke: no map_task spans in trace"; exit 1; }
    echo "trace smoke: Chrome trace shape present (python3 unavailable for full parse)"
fi

echo "== serve front smoke (bigfcm serve) =="
# The network front end-to-end on an ephemeral port: start the server
# (quick-trains a `default` model), score one record over the socket,
# hot-reload a second bundle over the wire (generation must bump to 2),
# then shut down cleanly via the wire verb.
PORT_FILE="$SMOKE_DIR/serve.addr"
cargo run --release --bin bigfcm -- serve \
    --port 0 --port-file "$PORT_FILE" \
    --dataset susy --dataset-records 2048 --clusters 3 &
SERVE_PID=$!
for _ in $(seq 1 150); do
    [ -s "$PORT_FILE" ] && break
    sleep 0.2
done
[ -s "$PORT_FILE" ] || { echo "serve never wrote $PORT_FILE"; kill "$SERVE_PID" 2>/dev/null; exit 1; }
ADDR="$(cat "$PORT_FILE")"

# susy records carry 18 features; any in-range row exercises the path.
ROW="0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5,0.5"
REPLY="$(cargo run --release --bin bigfcm -- serve --connect "$ADDR" \
    --send "score default smoke normal $ROW")"
case "$REPLY" in
    "ok 1 "*) echo "serve smoke: scored over the socket on generation 1" ;;
    *) echo "serve smoke: unexpected score reply: $REPLY"; kill "$SERVE_PID" 2>/dev/null; exit 1 ;;
esac

cargo run --release --bin bigfcm -- session \
    --dataset susy --records 2048 --clusters 3 --iters 3 \
    --save-model "$SMOKE_DIR/serve2.bfm"
REPLY="$(cargo run --release --bin bigfcm -- serve --connect "$ADDR" \
    --send "reload default $SMOKE_DIR/serve2.bfm")"
[ "$REPLY" = "ok 2" ] || { echo "serve smoke: unexpected reload reply: $REPLY"; kill "$SERVE_PID" 2>/dev/null; exit 1; }
REPLY="$(cargo run --release --bin bigfcm -- serve --connect "$ADDR" \
    --send "score default smoke high $ROW")"
case "$REPLY" in
    "ok 2 "*) echo "serve smoke: scored on generation 2 after hot reload" ;;
    *) echo "serve smoke: post-reload score reply: $REPLY"; kill "$SERVE_PID" 2>/dev/null; exit 1 ;;
esac

REPLY="$(cargo run --release --bin bigfcm -- serve --connect "$ADDR" --send "metrics")"
echo "$REPLY" | grep -q "# TYPE front_frames counter" \
    || { echo "serve smoke: metrics verb returned no exposition: $REPLY"; kill "$SERVE_PID" 2>/dev/null; exit 1; }
echo "serve smoke: Prometheus-style metrics exposition over the wire"

cargo run --release --bin bigfcm -- serve --connect "$ADDR" --send "shutdown" >/dev/null
wait "$SERVE_PID"
echo "serve smoke: clean shutdown"

echo "verify: OK"
