#!/usr/bin/env bash
# Tier-1 verification: format, release build, full test suite.
# Run from anywhere; operates on the rust/ crate.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
# Advisory for now: the seed predates format enforcement and was authored
# where rustfmt is unavailable, so drift is reported loudly but does not
# fail the gate. Flip to hard (drop the `|| true`) after running
# `cargo fmt` once on a machine with the toolchain and committing it.
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check || echo "WARNING: rustfmt drift above (advisory until the tree is formatted once)"
else
    echo "rustfmt unavailable in this toolchain; skipping format check"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== serve smoke (bigfcm serve-bench) =="
# The serving-layer acceptance in miniature: 2+ concurrent closed-loop
# clients must coalesce into micro-batches (batch fill > 1) and the p50/
# p95/p99 report must come out. A generous linger keeps this robust on
# loaded CI runners; --require-coalescing makes fill <= 1 a hard failure.
cargo run --release --bin bigfcm -- serve-bench \
    --clients 2 --records 200 --dataset-records 4096 --clusters 3 \
    --max-batch 32 --linger-us 2000 --json none --require-coalescing

echo "== score smoke (bigfcm score --quant i8) =="
# Bulk-scoring acceptance in miniature: train a tiny session model, then
# label the store through the quantized candidate pre-pass (approximate
# distances select candidates, exact math scores only those). Exercises
# the sidecar build, the top-k gather and the JobStats quant counters
# end-to-end on the release binary.
SMOKE_DIR="$(mktemp -d)"
trap 'rm -rf "$SMOKE_DIR"' EXIT
# C=6 with top-k 2 keeps 2k < C, so the candidate pre-pass actually
# engages (it falls back to exact scoring when 2k >= C).
cargo run --release --bin bigfcm -- session \
    --dataset susy --records 4096 --clusters 6 --iters 5 \
    --save-model "$SMOKE_DIR/smoke.bfm"
cargo run --release --bin bigfcm -- score \
    --dataset susy --records 4096 --topk 2 --quant i8 \
    --model "$SMOKE_DIR/smoke.bfm" --out "$SMOKE_DIR/scored"

echo "verify: OK"
