#!/usr/bin/env bash
# Perf-trajectory tracker: run the micro_hotpath bench, emit
# BENCH_micro_hotpath.json, and diff it against the committed baseline
# (rust/benches/BENCH_micro_hotpath.baseline.json).
#
# FAIL-SOFT BY DESIGN: this script always exits 0. Micro-benchmarks flake
# on shared CI runners; the diff is a comment-style report for humans (and
# the uploaded JSON artifact feeds EXPERIMENTS.md §Perf), not a gate.
set -uo pipefail

cd "$(dirname "$0")/../rust"

BASELINE="benches/BENCH_micro_hotpath.baseline.json"
CURRENT="BENCH_micro_hotpath.json"
# Mrec/s regressions beyond this fraction are flagged in the report.
THRESHOLD="${BENCH_DIFF_THRESHOLD:-0.10}"

echo "== cargo bench --bench micro_hotpath =="
if ! cargo bench --bench micro_hotpath; then
    echo "bench run failed (soft): nothing to diff"
    exit 0
fi

if [ ! -f "$CURRENT" ]; then
    echo "bench completed but $CURRENT was not emitted (soft)"
    exit 0
fi

if [ ! -f "$BASELINE" ]; then
    echo ""
    echo "no committed baseline at rust/$BASELINE — perf trajectory starts here."
    echo "to begin tracking, commit this run as the baseline:"
    echo "    cp rust/$CURRENT rust/$BASELINE && git add rust/$BASELINE"
    exit 0
fi

if ! command -v python3 >/dev/null 2>&1; then
    echo "python3 unavailable (soft): skipping diff"
    exit 0
fi

python3 - "$BASELINE" "$CURRENT" "$THRESHOLD" <<'EOF'
import json
import sys

base_path, cur_path, threshold = sys.argv[1], sys.argv[2], float(sys.argv[3])
base = json.load(open(base_path))["results"]
cur = json.load(open(cur_path))["results"]

print()
print("== micro_hotpath vs committed baseline ==")
print(f"{'label':<26} {'base Mrec/s':>12} {'now Mrec/s':>12} {'delta':>8}")
regressions = []
for key in sorted(set(base) | set(cur)):
    b = base.get(key, {}).get("mrec_per_s")
    c = cur.get(key, {}).get("mrec_per_s")
    if b is None or c is None:
        status = "baseline-only" if c is None else "new"
        print(f"{key:<26} {b or '-':>12} {c or '-':>12} {status:>8}")
        continue
    delta = (c - b) / b if b else 0.0
    mark = ""
    if delta < -threshold:
        mark = "  << REGRESSION"
        regressions.append((key, delta))
    print(f"{key:<26} {b:>12.2f} {c:>12.2f} {delta:>+7.1%}{mark}")

print()
if regressions:
    worst = ", ".join(f"{k} ({d:+.1%})" for k, d in regressions)
    print(f"report: {len(regressions)} label(s) slower than baseline by >{threshold:.0%}: {worst}")
    print("(fail-soft: not failing the build; investigate or refresh the baseline)")
else:
    print(f"report: no label slower than baseline by >{threshold:.0%}")
EOF

exit 0
