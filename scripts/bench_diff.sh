#!/usr/bin/env bash
# Perf-trajectory tracker: run the micro_hotpath bench, emit
# BENCH_micro_hotpath.json, and diff it against the committed baseline
# (rust/benches/BENCH_micro_hotpath.baseline.json). Then run the serve
# load harness (`bigfcm serve-bench`), emit BENCH_serve.json, and diff
# its throughput/latency counters against
# rust/benches/BENCH_serve.baseline.json.
#
# FAIL-SOFT BY DESIGN: this script always exits 0. Micro-benchmarks flake
# on shared CI runners; the diff is a comment-style report for humans (and
# the uploaded JSON artifact feeds EXPERIMENTS.md §Perf), not a gate.
set -uo pipefail

cd "$(dirname "$0")/../rust"

BASELINE="benches/BENCH_micro_hotpath.baseline.json"
CURRENT="BENCH_micro_hotpath.json"
# Mrec/s regressions beyond this fraction are flagged in the report.
THRESHOLD="${BENCH_DIFF_THRESHOLD:-0.10}"

echo "== cargo bench --bench micro_hotpath =="
if ! cargo bench --bench micro_hotpath; then
    echo "bench run failed (soft): nothing to diff"
    exit 0
fi

if [ ! -f "$CURRENT" ]; then
    echo "bench completed but $CURRENT was not emitted (soft)"
    exit 0
fi

if [ ! -f "$BASELINE" ]; then
    echo ""
    echo "no committed baseline at rust/$BASELINE — perf trajectory starts here."
    echo "to begin tracking, commit this run as the baseline:"
    echo "    cp rust/$CURRENT rust/$BASELINE && git add rust/$BASELINE"
    exit 0
fi

if ! command -v python3 >/dev/null 2>&1; then
    echo "python3 unavailable (soft): skipping diff"
    exit 0
fi

python3 - "$BASELINE" "$CURRENT" "$THRESHOLD" <<'EOF'
import json
import sys

base_path, cur_path, threshold = sys.argv[1], sys.argv[2], float(sys.argv[3])
base_doc = json.load(open(base_path))
cur_doc = json.load(open(cur_path))

# Apples-to-oranges guard: both files carry a config/params fingerprint
# (algo, bounds, quant, workers, seed, plus the shard topology: shards,
# merge mode, steal penalty). Refuse the diff when they disagree —
# numbers from different configs or cluster topologies are not a perf
# trajectory. Fail-soft: the report is skipped, the build is not failed.
# Baselines predating the hash (no config_hash key) diff as before.
bh, ch = base_doc.get("config_hash"), cur_doc.get("config_hash")
if bh and ch and bh != ch:
    print()
    print(f"refusing diff: config_hash mismatch (baseline {bh} vs current {ch})")
    print("the bench config or shard topology changed — refresh the baseline before tracking deltas:")
    print(f"    cp rust/{cur_path} rust/{base_path} && git add rust/{base_path}")
    sys.exit(0)

base = base_doc["results"]
cur = cur_doc["results"]

print()
print("== micro_hotpath vs committed baseline ==")
print(f"{'label':<26} {'base Mrec/s':>12} {'now Mrec/s':>12} {'delta':>8}")
regressions = []
for key in sorted(set(base) | set(cur)):
    b = base.get(key, {}).get("mrec_per_s")
    c = cur.get(key, {}).get("mrec_per_s")
    if b is None or c is None:
        status = "baseline-only" if c is None else "new"
        print(f"{key:<26} {b or '-':>12} {c or '-':>12} {status:>8}")
        continue
    delta = (c - b) / b if b else 0.0
    mark = ""
    if delta < -threshold:
        mark = "  << REGRESSION"
        regressions.append((key, delta))
    print(f"{key:<26} {b:>12.2f} {c:>12.2f} {delta:>+7.1%}{mark}")

print()
if regressions:
    worst = ", ".join(f"{k} ({d:+.1%})" for k, d in regressions)
    print(f"report: {len(regressions)} label(s) slower than baseline by >{threshold:.0%}: {worst}")
    print("(fail-soft: not failing the build; investigate or refresh the baseline)")
else:
    print(f"report: no label slower than baseline by >{threshold:.0%}")

# Session counters (iteration-resident A/B): reduce wall, pruning rate and
# combine-tree depth per push, diffed against the baseline when it has them.
base_sess = base_doc.get("session") or {}
cur_sess = cur_doc.get("session") or {}
if cur_sess:
    print()
    print("== iteration-residency counters (session vs per-job A/B) ==")
    keys = [
        "per_job_reduce_wall_s",
        "session_reduce_wall_s",
        "records_pruned",
        "records_pruned_dmin",
        "records_pruned_elkan",
        "records_pruned_elkan_quant",
        "records_pruned_quant",
        "quant_sidecar_bytes",
        "quant_build_s",
        "quant_modelled_s",
        "slab_spilled_bytes",
        "slab_reloads",
        "read_retries",
        "read_aborts",
        "quarantines",
        "prefetch_errors",
        "slab_spill_retries",
        "slab_spill_quarantines",
        "backoff_s",
        "checkpoints_written",
        "combine_depth",
        "per_job_modelled_s",
        "session_modelled_s",
        "dmin_modelled_s",
        "shard_steals",
        "shard_steal_ratio",
        "sharded_modelled_s",
        "sharded_objective",
    ]
    print(f"{'counter':<26} {'baseline':>14} {'now':>14}")
    for key in keys:
        b = base_sess.get(key)
        c = cur_sess.get(key)
        bs = f"{b:.6g}" if isinstance(b, (int, float)) else "-"
        cs = f"{c:.6g}" if isinstance(c, (int, float)) else "-"
        print(f"{key:<26} {bs:>14} {cs:>14}")
    pj, se = cur_sess.get("per_job_reduce_wall_s"), cur_sess.get("session_reduce_wall_s")
    if pj and se and pj > 0:
        print(f"reduce-wall ratio (session / per-job): {se / pj:.2f}x")
    if not cur_sess.get("records_pruned"):
        print("note: records_pruned == 0 this run — pruning never engaged; investigate")
    pd, pe = cur_sess.get("records_pruned_dmin"), cur_sess.get("records_pruned_elkan")
    if pd is not None and pe is not None and pe < pd:
        print(f"note: elkan pruned fewer records than dmin ({pe} < {pd}) — bound regression; investigate")
    # The quant second chance only runs on records plain elkan abandons,
    # so elkan+i8 pruning below plain elkan is structurally impossible —
    # if it shows up, the certified pre-pass regressed.
    pq = cur_sess.get("records_pruned_elkan_quant")
    if pq is not None and pe is not None and pq < pe:
        print(f"note: elkan+quant pruned fewer records than elkan ({pq} < {pe}) — quant pre-pass regression; investigate")
    # Recovery trajectory: retries recovering is the designed behavior;
    # retries *becoming aborts* means the retry budget stopped absorbing
    # the configured fault rate — a recovery regression, not noise.
    aborts = cur_sess.get("read_aborts") or 0
    if aborts > 0:
        retries = cur_sess.get("read_retries") or 0
        print(f"note: {aborts:.0f} read retries became aborts (retries {retries:.0f}) — recovery regression; investigate")
    base_aborts = base_sess.get("read_aborts") or 0
    if aborts > base_aborts:
        print(f"note: read_aborts rose vs baseline ({base_aborts:.0f} -> {aborts:.0f})")
    # Cross-shard steal trajectory: the steal ratio is a plan-time property
    # of the topology (same store, same shards, same workers), so any rise
    # vs baseline means the rebalance got hungrier — modelled rack traffic
    # crept into the scale-out headline; that is a scheduler regression,
    # not runner noise.
    br = base_sess.get("shard_steal_ratio")
    cr = cur_sess.get("shard_steal_ratio")
    if br is not None and cr is not None and cr > br + 1e-12:
        print(f"note: cross-shard steal ratio rose vs baseline ({br:.3f} -> {cr:.3f}) — plan-time rebalance regression; investigate")

# Tracing overhead A/B (observability gate): the bench runs the same
# chunked kernel pass with the global tracer off, then on, one span per
# chunk. Enabled tracing costing more than 3% of the hot path is flagged
# (fail-soft like everything above).
cur_trace = cur_doc.get("trace") or {}
frac = cur_trace.get("overhead_frac")
if frac is not None:
    off_s, on_s = cur_trace.get("off_s") or 0.0, cur_trace.get("on_s") or 0.0
    print()
    print("== tracing overhead A/B ==")
    print(f"tracer off {off_s * 1e3:.3f} ms, on {on_s * 1e3:.3f} ms -> overhead {frac:+.2%}")
    if frac > 0.03:
        print(f"note: tracing-enabled overhead {frac:+.2%} exceeds the 3% budget — span hot path regression; investigate")
    bfrac = (base_doc.get("trace") or {}).get("overhead_frac")
    if bfrac is not None and frac - bfrac > 0.03:
        print(f"note: tracing overhead rose vs baseline ({bfrac:+.2%} -> {frac:+.2%})")
EOF

# ---------------------------------------------------------------------------
# Serving-layer counters (bigfcm serve-bench) — same fail-soft discipline.
# ---------------------------------------------------------------------------

SERVE_BASELINE="benches/BENCH_serve.baseline.json"
SERVE_CURRENT="BENCH_serve.json"

echo ""
echo "== bigfcm serve-bench (open-loop) =="
# Open-loop: arrivals at a fixed rate independent of completions, each
# latency measured from the scheduled arrival — the mode whose p99 an SLO
# can honestly be stated against (closed-loop p99 hides queueing delay
# behind client back-to-back pacing).
if ! cargo run --release --bin bigfcm -- serve-bench \
        --dataset-records 16384 --clusters 4 \
        --open-loop --rate 2000 --duration-s 2.0 --p99-target-us 5000 --inflight 64 \
        --json "$SERVE_CURRENT"; then
    echo "serve-bench run failed (soft): nothing to diff"
    exit 0
fi

if [ ! -f "$SERVE_CURRENT" ]; then
    echo "serve-bench completed but $SERVE_CURRENT was not emitted (soft)"
    exit 0
fi

if [ ! -f "$SERVE_BASELINE" ]; then
    echo ""
    echo "no committed serve baseline at rust/$SERVE_BASELINE — serving trajectory starts here."
    echo "to begin tracking, commit this run as the baseline:"
    echo "    cp rust/$SERVE_CURRENT rust/$SERVE_BASELINE && git add rust/$SERVE_BASELINE"
    exit 0
fi

if ! command -v python3 >/dev/null 2>&1; then
    echo "python3 unavailable (soft): skipping serve diff"
    exit 0
fi

python3 - "$SERVE_BASELINE" "$SERVE_CURRENT" "$THRESHOLD" <<'EOF'
import json
import sys

base_path, cur_path, threshold = sys.argv[1], sys.argv[2], float(sys.argv[3])
base_doc = json.load(open(base_path))
cur_doc = json.load(open(cur_path))

# Same config-fingerprint refusal as the micro_hotpath diff above.
bh, ch = base_doc.get("config_hash"), cur_doc.get("config_hash")
if bh and ch and bh != ch:
    print()
    print(f"refusing serve diff: config_hash mismatch (baseline {bh} vs current {ch})")
    print("the serve config changed — refresh the baseline before tracking deltas:")
    print(f"    cp rust/{cur_path} rust/{base_path} && git add rust/{base_path}")
    sys.exit(0)

base = base_doc.get("serve") or {}
cur = cur_doc.get("serve") or {}

print()
print("== serve-bench vs committed baseline ==")
keys = [
    "throughput_rps",
    "target_rps",
    "achieved_rps",
    "batch_fill",
    "pad_utilization",
    "p50_us",
    "p95_us",
    "p99_us",
    "open_p50_us",
    "open_p95_us",
    "open_p99_us",
    "slo_p99_target_us",
    "slo_attained",
    "slo_ok_fraction",
    "queue_peak",
    "backpressure_waits",
    "quota_rejections",
    "deprioritized",
    "deadline_shed",
    "overload_shed",
    "errors",
]
print(f"{'counter':<22} {'baseline':>14} {'now':>14}")
for key in keys:
    b, c = base.get(key), cur.get(key)
    bs = f"{b:.6g}" if isinstance(b, (int, float)) else "-"
    cs = f"{c:.6g}" if isinstance(c, (int, float)) else "-"
    print(f"{key:<22} {bs:>14} {cs:>14}")

issues = []
bt, ct = base.get("throughput_rps"), cur.get("throughput_rps")
if bt and ct and (ct - bt) / bt < -threshold:
    issues.append(f"throughput {ct:.0f} rps vs baseline {bt:.0f} ({(ct - bt) / bt:+.1%})")
fill = cur.get("batch_fill")
if fill is not None and fill <= 1.0:
    issues.append(f"batch fill {fill:.2f} <= 1 — micro-batching is not coalescing")
bp, cp = base.get("p95_us"), cur.get("p95_us")
if bp and cp and (cp - bp) / bp > threshold:
    issues.append(f"p95 latency {cp:.0f} us vs baseline {bp:.0f} ({(cp - bp) / bp:+.1%})")
if cur.get("errors"):
    issues.append(f"{cur['errors']:.0f} request(s) errored")
shed = (cur.get("deadline_shed") or 0) + (cur.get("overload_shed") or 0)
base_shed = (base.get("deadline_shed") or 0) + (base.get("overload_shed") or 0)
if shed > base_shed:
    issues.append(
        f"degraded-mode shedding rose vs baseline ({base_shed:.0f} -> {shed:.0f} requests shed)"
    )

# Open-loop SLO trajectory: attainment flipping 1 -> 0 is the headline
# regression; a large drop in the within-target fraction flags even when
# the binary verdict holds.
ba, ca = base.get("slo_attained"), cur.get("slo_attained")
if ba == 1 and ca == 0:
    issues.append(
        f"SLO attainment dropped: open-loop p99 {cur.get('open_p99_us', 0):.0f} us exceeds "
        f"target {cur.get('slo_p99_target_us', 0):.0f} us (baseline attained it)"
    )
bf, cf = base.get("slo_ok_fraction"), cur.get("slo_ok_fraction")
if bf is not None and cf is not None and bf - cf > threshold:
    issues.append(f"slo_ok_fraction {cf:.3f} vs baseline {bf:.3f} ({cf - bf:+.3f})")

print()
if issues:
    print("report: " + "; ".join(issues))
    print("(fail-soft: not failing the build; investigate or refresh the baseline)")
else:
    print(f"report: serve counters within {threshold:.0%} of baseline, batch fill > 1")
EOF

exit 0
