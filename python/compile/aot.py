"""AOT compile path: lower every Layer-2 graph the experiments need to HLO
*text* artifacts the rust runtime loads via PJRT.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` rust crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  <graph>_d<dims>_c<c>.hlo.txt   one per artifact matrix entry
  manifest.json                  artifact registry the rust runtime reads
  golden.json                    deterministic input/output vectors from the
                                 pure-jnp oracle, cross-checked by rust tests

Usage:  cd python && python -m compile.aot [--out-dir DIR] [--only NAME]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import fcm_pallas, ref

# Rows per chunk across the whole system.  The rust coordinator zero-pads the
# last chunk of every partition; padded rows carry weight 0 and are exactly
# ignored by all three graphs.
CHUNK = 4096

# (dims, clusters) combos required by the experiment matrix (DESIGN.md §5):
#   iris(4,3)  pima(8,2)  susy(18, {2,6,10})  higgs(28, {2,6,10,15,50})
#   kdd99(41, 23)
SHAPES = [
    (4, 3),
    (8, 2),
    (18, 2),
    (18, 6),
    (18, 10),
    (28, 2),
    (28, 6),
    (28, 10),
    (28, 15),
    (28, 50),
    (41, 23),
]

GRAPHS = ["fcm", "classic", "kmeans"]


def artifact_name(graph, d, c):
    return f"{graph}_d{d}_c{c}"


def artifact_matrix():
    """The full list of artifacts to build: one per (graph, dims, C)."""
    return [(artifact_name(g, d, c), g, d, c) for g in GRAPHS for (d, c) in SHAPES]


def to_hlo_text(lowered):
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the rust
    side unwraps a single tuple output)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(graph, d, c, chunk=CHUNK):
    fn = model.GRAPHS[graph]
    args = model.example_args(graph, chunk, d, c)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def _golden_case(graph, d, c, n=CHUNK, seed=0):
    """Deterministic small input + oracle output, for rust cross-checks.

    Uses a fixed key so the vectors are stable across runs/machines; values
    are round-tripped through float32.
    """
    key = jax.random.PRNGKey(seed)
    kx, kv, kw = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n, d), jnp.float32) * 2.0 + 0.5
    v = jax.random.normal(kv, (c, d), jnp.float32)
    w = jnp.abs(jax.random.normal(kw, (n,), jnp.float32)) + 0.1
    # Zero-weight tail exercises the padding contract.
    w = w.at[n - n // 8 :].set(0.0)
    m = 1.7
    if graph == "fcm":
        out = ref.fcm_chunk_step(x, v, w, m)
    elif graph == "classic":
        out = ref.classic_fcm_chunk_step(x, v, w, m)
    else:
        out = ref.kmeans_chunk_step(x, v, w)
    return {
        "graph": graph,
        "dims": d,
        "clusters": c,
        "chunk": n,
        "m": m,
        "x": [float(t) for t in x.reshape(-1)],
        "v": [float(t) for t in v.reshape(-1)],
        "w": [float(t) for t in w],
        "out_vnum": [float(t) for t in out[0].reshape(-1)],
        "out_wacc": [float(t) for t in out[1].reshape(-1)],
        "out_obj": float(out[2]),
    }


def build(out_dir, only=None, golden=True):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"chunk": CHUNK, "row_block": fcm_pallas.ROW_BLOCK, "artifacts": []}
    for name, graph, d, c in artifact_matrix():
        if only and only not in name:
            continue
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = lower_artifact(graph, d, c)
        with open(path, "w") as f:
            f.write(text)
        n_params = 3 if graph == "kmeans" else 4
        manifest["artifacts"].append(
            {
                "name": name,
                "graph": graph,
                "dims": d,
                "clusters": c,
                "chunk": CHUNK,
                "params": n_params,
                "file": f"{name}.hlo.txt",
                "bytes": len(text),
            }
        )
        print(f"  {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if golden and not only:
        # Small-chunk golden vectors (chunk=512 keeps the JSON manageable but
        # still crosses one ROW_BLOCK boundary when ROW_BLOCK=512).
        cases = [
            _golden_case("fcm", 4, 3, n=512, seed=0),
            _golden_case("fcm", 18, 2, n=512, seed=1),
            _golden_case("classic", 4, 3, n=512, seed=2),
            _golden_case("kmeans", 18, 2, n=512, seed=3),
        ]
        with open(os.path.join(out_dir, "golden.json"), "w") as f:
            json.dump({"cases": cases}, f)
        print(f"  golden.json: {len(cases)} cases")
    print(f"wrote {len(manifest['artifacts'])} artifacts to {out_dir}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    ap.add_argument("--no-golden", action="store_true")
    args = ap.parse_args()
    build(args.out_dir, only=args.only, golden=not args.no_golden)


if __name__ == "__main__":
    main()
