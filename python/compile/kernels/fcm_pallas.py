"""Layer-1 Pallas kernels: the per-chunk compute hot spots of BigFCM.

Every kernel processes one fixed-shape *chunk* of records and emits partial
sufficient statistics; the rust coordinator (Layer 3) owns the outer FCM
iteration loop, aggregates partials across chunks and nodes, and applies the
center update.  Keeping only sufficient statistics in the kernel interface is
what makes the MapReduce decomposition of the paper exact: partial sums are
associative, so combiner-side accumulation is algebraically identical to a
single-node pass.

Kernels (all lowered with ``interpret=True`` — the CPU PJRT client cannot run
Mosaic custom-calls; real-TPU projections live in DESIGN.md §Perf):

* ``fcm_chunk_step``      — Kolen–Hutcheson fast FCM (paper Eq. 5 /
  Algorithm 1): computes the membership *term* ``u^m`` directly, never
  materialising the membership matrix, O(n·c) per point-block.
* ``classic_fcm_chunk_step`` — textbook FCM membership via the (C×C) ratio
  tensor, O(n·c²).  This is the "basic FCM" the paper contrasts against and
  the compute model of the Mahout Fuzzy K-Means baseline.
* ``kmeans_chunk_step``   — hard-assignment partials (Mahout K-Means
  baseline): one-hot argmin, per-cluster sums/counts/SSE.

Tiling: the grid walks row-blocks of the chunk; the (C, d) center block and
the (C,)/(C, d) accumulators stay resident across grid steps (same block
mapped at every step), which is the VMEM-resident-stationary schedule — the
analogue of the paper's "centers in the distributed cache, records streamed".
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step.  (ROW_BLOCK × d) + (C × d) + (ROW_BLOCK × C) f32 must
# fit VMEM; for the largest artifact (d=41, C=50) this is
# 512×41 + 50×41 + 512×50 ≈ 0.19 MB — far under the ~16 MB budget, leaving
# room for double-buffering the streamed row block.
ROW_BLOCK = 512

_DIST_EPS = 1e-12  # clamp for zero distances (record sitting on a center)


def _dist2_tile(x, v):
    """Squared Euclidean distances ‖x−v‖² for a (B, d) row tile against
    (C, d) centers, in the matmul form ‖x‖² − 2x·Vᵀ + ‖V‖² so the bulk of
    the FLOPs land on the MXU.  Returns (B, C), clamped to be positive."""
    xx = jnp.sum(x * x, axis=1, keepdims=True)  # (B, 1)
    vv = jnp.sum(v * v, axis=1)[None, :]  # (1, C)
    xv = jnp.dot(x, v.T, preferred_element_type=jnp.float32)  # (B, C)
    d2 = xx - 2.0 * xv + vv
    return jnp.maximum(d2, _DIST_EPS)


def _um_fast(d2, m):
    """Kolen–Hutcheson membership term u^m from squared distances.

    numerator_i  = d_i^(2/(m-1)) = (d²_i)^(1/(m-1))
    denominator  = Σ_j 1/numerator_j
    u_i^m        = (numerator_i · denominator)^(−m)

    Derivation: u_i = 1 / Σ_j (d_i/d_j)^(2/(m-1)) = (num_i · den)^(−1),
    so raising to m gives the center-update weight directly — the membership
    matrix itself is never needed (paper Algorithm 1; Kolen & Hutcheson 2002).

    f32 robustness: memberships depend only on distance *ratios*, so we
    normalise by the row minimum before powering. Without this, small
    distances underflow (e.g. (1e-12)^5 → 0 in f32 at m=1.2) and produce
    inf·0 = NaN.
    """
    p = 1.0 / (m - 1.0)
    dmin = jnp.min(d2, axis=1, keepdims=True)  # (B, 1), > 0 by clamp
    num = jnp.power(d2 / dmin, p)  # (B, C), min entry = 1
    den = jnp.sum(1.0 / num, axis=1, keepdims=True)  # (B, 1), in [1, C]
    return jnp.power(num * den, -m)  # (B, C)


def _u_classic(d2, m):
    """Textbook FCM membership via the explicit (B, C, C) ratio tensor —
    deliberately O(c²) per point to model "basic FCM" faithfully."""
    p = 1.0 / (m - 1.0)
    ratios = jnp.power(d2[:, :, None] / d2[:, None, :], p)  # (B, C, C)
    return 1.0 / jnp.sum(ratios, axis=2)  # (B, C)


# ---------------------------------------------------------------------------
# fcm_chunk_step — fast (Kolen–Hutcheson) weighted FCM partials
# ---------------------------------------------------------------------------


def _fcm_kernel(x_ref, v_ref, w_ref, m_ref, vnum_ref, wacc_ref, obj_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        vnum_ref[...] = jnp.zeros_like(vnum_ref)
        wacc_ref[...] = jnp.zeros_like(wacc_ref)
        obj_ref[...] = jnp.zeros_like(obj_ref)

    x = x_ref[...]  # (B, d)
    v = v_ref[...]  # (C, d)
    w = w_ref[...]  # (B, 1)
    m = m_ref[0, 0]

    d2 = _dist2_tile(x, v)  # (B, C)
    um = _um_fast(d2, m) * w  # (B, C) weighted membership terms
    # Partial center numerators: Σ_k u^m_{ik} w_k x_k  → (C, d) via MXU.
    vnum_ref[...] += jnp.dot(um.T, x, preferred_element_type=jnp.float32)
    wacc_ref[...] += jnp.sum(um, axis=0, keepdims=True)  # (1, C)
    # Weighted objective partial  Σ u^m w ‖x−v‖²  (paper Eq. 2).
    obj_ref[...] += jnp.sum(um * d2, keepdims=True).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fcm_chunk_step(x, v, w, m, *, interpret=True):
    """One fast-FCM pass over a chunk.

    Args:
      x: (chunk, d) records.
      v: (C, d) current centers.
      w: (chunk,) record weights (0 ⇒ padded row, exactly ignored).
      m: scalar fuzzifier (> 1).

    Returns:
      (v_num (C, d), w_acc (C,), obj ()) partial sufficient statistics.
    """
    chunk, d = x.shape
    c = v.shape[0]
    blk = min(ROW_BLOCK, chunk)
    assert chunk % blk == 0, (chunk, blk)
    grid = (chunk // blk,)
    w2 = w.reshape(chunk, 1).astype(jnp.float32)
    m2 = jnp.asarray(m, jnp.float32).reshape(1, 1)
    vnum, wacc, obj = pl.pallas_call(
        _fcm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),  # stream row blocks
            pl.BlockSpec((c, d), lambda i: (0, 0)),  # centers resident
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),  # weights stream
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # fuzzifier
        ],
        out_specs=[
            pl.BlockSpec((c, d), lambda i: (0, 0)),  # accumulators resident
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, d), jnp.float32),
            jax.ShapeDtypeStruct((1, c), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x.astype(jnp.float32), v.astype(jnp.float32), w2, m2)
    return vnum, wacc.reshape(c), obj.reshape(())


# ---------------------------------------------------------------------------
# classic_fcm_chunk_step — textbook membership (O(n·c²)), for the baseline
# ---------------------------------------------------------------------------


def _classic_kernel(x_ref, v_ref, w_ref, m_ref, vnum_ref, wacc_ref, obj_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        vnum_ref[...] = jnp.zeros_like(vnum_ref)
        wacc_ref[...] = jnp.zeros_like(wacc_ref)
        obj_ref[...] = jnp.zeros_like(obj_ref)

    x = x_ref[...]
    v = v_ref[...]
    w = w_ref[...]
    m = m_ref[0, 0]

    d2 = _dist2_tile(x, v)
    u = _u_classic(d2, m)  # (B, C) true memberships
    um = jnp.power(u, m) * w  # classic update still weights by u^m
    vnum_ref[...] += jnp.dot(um.T, x, preferred_element_type=jnp.float32)
    wacc_ref[...] += jnp.sum(um, axis=0, keepdims=True)
    obj_ref[...] += jnp.sum(um * d2, keepdims=True).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def classic_fcm_chunk_step(x, v, w, m, *, interpret=True):
    """Textbook-FCM chunk pass (same interface as :func:`fcm_chunk_step`)."""
    chunk, d = x.shape
    c = v.shape[0]
    blk = min(ROW_BLOCK, chunk)
    assert chunk % blk == 0, (chunk, blk)
    w2 = w.reshape(chunk, 1).astype(jnp.float32)
    m2 = jnp.asarray(m, jnp.float32).reshape(1, 1)
    vnum, wacc, obj = pl.pallas_call(
        _classic_kernel,
        grid=(chunk // blk,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((c, d), lambda i: (0, 0)),
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((c, d), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, d), jnp.float32),
            jax.ShapeDtypeStruct((1, c), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x.astype(jnp.float32), v.astype(jnp.float32), w2, m2)
    return vnum, wacc.reshape(c), obj.reshape(())


# ---------------------------------------------------------------------------
# kmeans_chunk_step — hard-assignment partials for the Mahout-KM baseline
# ---------------------------------------------------------------------------


def _kmeans_kernel(x_ref, v_ref, w_ref, sums_ref, cnt_ref, sse_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        sse_ref[...] = jnp.zeros_like(sse_ref)

    x = x_ref[...]
    v = v_ref[...]
    w = w_ref[...]

    d2 = _dist2_tile(x, v)  # (B, C)
    c = v.shape[0]
    best = jnp.argmin(d2, axis=1)  # (B,)
    onehot = (best[:, None] == jnp.arange(c)[None, :]).astype(jnp.float32) * w
    sums_ref[...] += jnp.dot(onehot.T, x, preferred_element_type=jnp.float32)
    cnt_ref[...] += jnp.sum(onehot, axis=0, keepdims=True)
    sse_ref[...] += jnp.sum(onehot * d2, keepdims=True).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def kmeans_chunk_step(x, v, w, *, interpret=True):
    """One hard K-Means pass over a chunk.

    Returns (sums (C, d), counts (C,), sse ()).  ``w`` is 1 for live rows and
    0 for padding (fractional weights are also honoured).
    """
    chunk, d = x.shape
    c = v.shape[0]
    blk = min(ROW_BLOCK, chunk)
    assert chunk % blk == 0, (chunk, blk)
    w2 = w.reshape(chunk, 1).astype(jnp.float32)
    sums, cnt, sse = pl.pallas_call(
        _kmeans_kernel,
        grid=(chunk // blk,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((c, d), lambda i: (0, 0)),
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((c, d), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, d), jnp.float32),
            jax.ShapeDtypeStruct((1, c), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x.astype(jnp.float32), v.astype(jnp.float32), w2)
    return sums, cnt.reshape(c), sse.reshape(())
