"""Pure-jnp oracles for the Pallas kernels and the full FCM loop.

These are the correctness ground truth: ``python/tests`` asserts the Pallas
kernels (interpret mode) match these to float32 tolerance, and the rust-native
implementations are cross-checked against the same math via golden vectors
emitted by ``aot.py --golden``.
"""

import jax.numpy as jnp

_DIST_EPS = 1e-12


def dist2(x, v):
    """Pairwise squared Euclidean distances, (N, d) × (C, d) → (N, C)."""
    xx = jnp.sum(x * x, axis=1, keepdims=True)
    vv = jnp.sum(v * v, axis=1)[None, :]
    d2 = xx - 2.0 * (x @ v.T) + vv
    return jnp.maximum(d2, _DIST_EPS)


def memberships(x, v, m):
    """True FCM membership matrix U (N, C), rows sum to 1.

    Distances are normalised by the row minimum before powering — the
    memberships depend only on ratios, and this keeps f32 from underflowing
    at small m (see fcm_pallas._um_fast)."""
    d2 = dist2(x, v)
    p = 1.0 / (m - 1.0)
    dmin = jnp.min(d2, axis=1, keepdims=True)
    num = jnp.power(d2 / dmin, p)
    den = jnp.sum(1.0 / num, axis=1, keepdims=True)
    return 1.0 / (num * den)


def um_fast(x, v, m):
    """Kolen–Hutcheson membership term u^m, computed without U."""
    d2 = dist2(x, v)
    p = 1.0 / (m - 1.0)
    dmin = jnp.min(d2, axis=1, keepdims=True)
    num = jnp.power(d2 / dmin, p)
    den = jnp.sum(1.0 / num, axis=1, keepdims=True)
    return jnp.power(num * den, -m)


def fcm_chunk_step(x, v, w, m):
    """Oracle for kernels.fcm_pallas.fcm_chunk_step."""
    um = um_fast(x, v, m) * w[:, None]
    v_num = um.T @ x
    w_acc = jnp.sum(um, axis=0)
    obj = jnp.sum(um * dist2(x, v))
    return v_num, w_acc, obj


def classic_fcm_chunk_step(x, v, w, m):
    """Oracle for kernels.fcm_pallas.classic_fcm_chunk_step."""
    u = memberships(x, v, m)
    um = jnp.power(u, m) * w[:, None]
    v_num = um.T @ x
    w_acc = jnp.sum(um, axis=0)
    obj = jnp.sum(um * dist2(x, v))
    return v_num, w_acc, obj


def kmeans_chunk_step(x, v, w):
    """Oracle for kernels.fcm_pallas.kmeans_chunk_step."""
    d2 = dist2(x, v)
    best = jnp.argmin(d2, axis=1)
    onehot = (best[:, None] == jnp.arange(v.shape[0])[None, :]) * w[:, None]
    sums = onehot.T @ x
    counts = jnp.sum(onehot, axis=0)
    sse = jnp.sum(onehot * d2)
    return sums, counts, sse


def fcm_full(x, v0, m, eps, max_iter, w=None):
    """Complete weighted-FCM loop (the algorithm rust's L3 implements around
    the chunk step).  Returns (centers, final weights, iterations, obj)."""
    v = v0
    w = jnp.ones(x.shape[0]) if w is None else w
    it = 0
    obj = jnp.inf
    w_acc = jnp.zeros(v0.shape[0])
    for it in range(1, max_iter + 1):
        v_num, w_acc, obj = fcm_chunk_step(x, v, w, m)
        v_new = v_num / jnp.maximum(w_acc[:, None], 1e-30)
        shift = jnp.max(jnp.sum((v_new - v) ** 2, axis=1))
        v = v_new
        if float(shift) <= eps:
            break
    return v, w_acc, it, obj


def kmeans_full(x, v0, eps, max_iter):
    """Complete Lloyd's loop around the kmeans chunk step."""
    v = v0
    w = jnp.ones(x.shape[0])
    it = 0
    sse = jnp.inf
    for it in range(1, max_iter + 1):
        sums, counts, sse = kmeans_chunk_step(x, v, w)
        # Empty clusters keep their previous center (Mahout behaviour).
        safe = jnp.maximum(counts[:, None], 1e-30)
        v_new = jnp.where(counts[:, None] > 0, sums / safe, v)
        shift = jnp.max(jnp.sum((v_new - v) ** 2, axis=1))
        v = v_new
        if float(shift) <= eps:
            break
    return v, it, sse
