"""Layer-2 JAX compute graphs, lowered once by ``aot.py`` to HLO text.

Each graph wraps a Layer-1 Pallas kernel and is the unit the rust runtime
executes per chunk.  Rust owns everything around it: the iteration loop,
the convergence test, padding, aggregation across chunks/workers, and the
final center division (kept host-side so partials stay associative).

Graph signatures (all f32, shapes fixed per artifact):

  fcm_chunk_step      (chunk,d), (C,d), (chunk,), ()  -> (C,d), (C,), ()
  classic_fcm_chunk   same                            -> same
  kmeans_chunk_step   (chunk,d), (C,d), (chunk,)      -> (C,d), (C,), ()
"""

import jax
import jax.numpy as jnp

from compile.kernels import fcm_pallas


def fcm_chunk_step(x, v, w, m):
    """Fast-FCM (Kolen–Hutcheson) chunk partials — the BigFCM hot path."""
    v_num, w_acc, obj = fcm_pallas.fcm_chunk_step(x, v, w, m)
    return v_num, w_acc, obj


def classic_fcm_chunk_step(x, v, w, m):
    """Textbook-FCM chunk partials — the Mahout-FKM baseline hot path."""
    v_num, w_acc, obj = fcm_pallas.classic_fcm_chunk_step(x, v, w, m)
    return v_num, w_acc, obj


def kmeans_chunk_step(x, v, w):
    """Hard K-Means chunk partials — the Mahout-KM baseline hot path."""
    sums, counts, sse = fcm_pallas.kmeans_chunk_step(x, v, w)
    return sums, counts, sse


GRAPHS = {
    "fcm": fcm_chunk_step,
    "classic": classic_fcm_chunk_step,
    "kmeans": kmeans_chunk_step,
}


def example_args(graph, chunk, d, c):
    """ShapeDtypeStructs used to lower a graph for a given artifact shape."""
    f32 = jnp.float32
    x = jax.ShapeDtypeStruct((chunk, d), f32)
    v = jax.ShapeDtypeStruct((c, d), f32)
    w = jax.ShapeDtypeStruct((chunk,), f32)
    m = jax.ShapeDtypeStruct((), f32)
    if graph == "kmeans":
        return (x, v, w)
    return (x, v, w, m)
