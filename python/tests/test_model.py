"""Layer-2 model graph tests: shapes, the outer-loop oracle, and the
convergence behaviour the paper's design relies on."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _blobs(n, d, c, seed, spread=0.3):
    """c well-separated Gaussian blobs (n total records)."""
    key = jax.random.PRNGKey(seed)
    kc, kx = jax.random.split(key)
    centers = jax.random.normal(kc, (c, d), jnp.float32) * 4.0
    assign = jnp.arange(n) % c
    noise = jax.random.normal(kx, (n, d), jnp.float32) * spread
    return centers[assign] + noise, centers


def test_graph_shapes():
    for graph in model.GRAPHS:
        args = model.example_args(graph, 256, 8, 4)
        assert args[0].shape == (256, 8)
        assert args[1].shape == (4, 8)
        assert args[2].shape == (256,)
        if graph != "kmeans":
            assert args[3].shape == ()


def test_graphs_lower_without_error():
    """Every graph traces and lowers at a small shape (fast sanity ahead of
    the full AOT matrix)."""
    for graph, fn in model.GRAPHS.items():
        args = model.example_args(graph, 64, 4, 3)
        lowered = jax.jit(fn).lower(*args)
        assert lowered is not None


def test_fcm_objective_decreases():
    """The weighted objective (paper Eq. 2) is non-increasing along the
    FCM iteration — the Lyapunov property the convergence test relies on."""
    x, _ = _blobs(512, 4, 3, 0)
    v = x[:3] + 0.5
    objs = []
    w = jnp.ones(512)
    for _ in range(8):
        v_num, w_acc, obj = ref.fcm_chunk_step(x, v, w, 2.0)
        objs.append(float(obj))
        v = v_num / jnp.maximum(w_acc[:, None], 1e-30)
    # Allow tiny float wiggle at the converged tail.
    for a, b in zip(objs, objs[1:]):
        assert b <= a * (1.0 + 1e-4), objs


def test_fcm_full_recovers_blobs():
    """On well-separated blobs the full loop recovers the true centers."""
    x, true_centers = _blobs(900, 3, 3, 1, spread=0.15)
    v0 = x[jnp.asarray([0, 1, 2])] + 0.3
    v, _, iters, _ = ref.fcm_full(x, v0, 2.0, 1e-10, 200)
    # Match each found center to its nearest true center.
    d2 = ref.dist2(v, true_centers)
    err = float(jnp.max(jnp.min(d2, axis=1)))
    assert err < 0.05, (err, iters)
    assert iters < 200


def test_kmeans_full_recovers_blobs():
    x, true_centers = _blobs(900, 3, 3, 2, spread=0.15)
    v0 = x[jnp.asarray([0, 1, 2])] + 0.3
    v, iters, _ = ref.kmeans_full(x, v0, 1e-10, 200)
    d2 = ref.dist2(v, true_centers)
    assert float(jnp.max(jnp.min(d2, axis=1))) < 0.05
    assert iters < 200


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_warm_start_converges_no_slower(seed):
    """The paper's driver claim (Table 2): seeding with approximate centers
    does not *materially increase* the iteration count vs a mismatched
    start. Individual runs are noisy (different basins can have different
    local convergence rates), so the bound is statistical: warm must not
    exceed 1.5x cold + 5."""
    x, true_centers = _blobs(600, 4, 3, seed, spread=0.3)
    key = jax.random.PRNGKey(seed + 7)
    cold0 = jax.random.normal(key, true_centers.shape, jnp.float32) * 4.0
    warm0 = true_centers + 0.05
    # eps must stay above the f32 center-shift noise floor (~1e-12) or a
    # symmetric start can oscillate forever without "converging".
    _, _, it_cold, _ = ref.fcm_full(x, cold0, 2.0, 1e-8, 500)
    _, _, it_warm, _ = ref.fcm_full(x, warm0, 2.0, 1e-8, 500)
    assert it_warm <= it_cold * 1.5 + 5, (it_warm, it_cold)


def test_weighted_merge_equals_full_pass_on_split():
    """WFCM over per-partition (centers, weights) approximates the
    full-data FCM — the core BigFCM soundness argument.  With partitions
    that are random splits (iid), one fast-FCM step from the same seeds
    followed by the weighted merge must land close to the full-data step."""
    x, _ = _blobs(1024, 4, 3, 3, spread=0.4)
    v_seed = x[jnp.asarray([0, 1, 2])]
    w = jnp.ones(1024)

    # Full-data one-step update.
    v_num, w_acc, _ = ref.fcm_chunk_step(x, v_seed, w, 2.0)
    v_full = v_num / w_acc[:, None]

    # Two-partition update + weighted merge (per-cluster weighted average).
    merged_num = jnp.zeros_like(v_num)
    merged_wacc = jnp.zeros_like(w_acc)
    for part in (x[:512], x[512:]):
        pn, pw, _ = ref.fcm_chunk_step(part, v_seed, jnp.ones(part.shape[0]), 2.0)
        merged_num = merged_num + pn
        merged_wacc = merged_wacc + pw
    v_merged = merged_num / merged_wacc[:, None]

    np.testing.assert_allclose(
        np.asarray(v_merged), np.asarray(v_full), rtol=1e-4, atol=1e-4
    )
