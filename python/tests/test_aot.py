"""AOT pipeline tests: the artifact matrix lowers to loadable HLO text and
the golden vectors are self-consistent."""

import json
import os
import tempfile

import pytest

from compile import aot, model


def test_artifact_matrix_covers_experiments():
    names = {n for n, _, _, _ in aot.artifact_matrix()}
    # Every experiment combo from DESIGN.md §5 must be present for all graphs.
    for g in ("fcm", "classic", "kmeans"):
        for d, c in [(4, 3), (8, 2), (18, 2), (28, 50), (41, 23)]:
            assert f"{g}_d{d}_c{c}" in names


def test_lowered_hlo_is_text_module():
    text = aot.lower_artifact("fcm", 4, 3, chunk=64)
    assert text.startswith("HloModule")
    assert "f32[64,4]" in text  # x param at the requested shape
    assert "f32[3,4]" in text  # centers param


def test_kmeans_has_three_params():
    text = aot.lower_artifact("kmeans", 4, 3, chunk=64)
    assert text.startswith("HloModule")
    # kmeans takes (x, v, w) — no fuzzifier scalar in the entry layout.
    layout = text.splitlines()[0]
    params = layout.split("entry_computation_layout={(")[1].split(")->")[0]
    assert "f32[]" not in params, params
    assert params.count("f32[") == 3, params


def test_build_writes_manifest_and_artifacts():
    with tempfile.TemporaryDirectory() as td:
        # Build just one artifact (substring filter) without golden vectors.
        aot.build(td, only="fcm_d4_c3", golden=False)
        manifest = json.load(open(os.path.join(td, "manifest.json")))
        assert manifest["chunk"] == aot.CHUNK
        arts = manifest["artifacts"]
        assert len(arts) == 1
        a = arts[0]
        assert a["name"] == "fcm_d4_c3"
        assert a["params"] == 4
        path = os.path.join(td, a["file"])
        assert os.path.exists(path)
        assert open(path).read().startswith("HloModule")


def test_golden_case_roundtrip():
    case = aot._golden_case("fcm", 4, 3, n=64, seed=0)
    assert len(case["x"]) == 64 * 4
    assert len(case["v"]) == 3 * 4
    assert len(case["out_vnum"]) == 3 * 4
    assert len(case["out_wacc"]) == 3
    # Zero-weight tail present (padding contract exercised).
    assert any(w == 0.0 for w in case["w"])
    assert all(w >= 0.0 for w in case["w"])


@pytest.mark.parametrize("graph", ["fcm", "classic", "kmeans"])
def test_each_graph_lowers_at_production_combo(graph):
    """One full-size lowering per graph (smoke for the matrix build)."""
    text = aot.lower_artifact(graph, 18, 6, chunk=aot.CHUNK)
    assert text.startswith("HloModule")
    assert f"f32[{aot.CHUNK},18]" in text
