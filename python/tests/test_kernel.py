"""Pallas kernels (interpret mode) vs the pure-jnp oracle — the core
correctness signal for Layer 1.

Hypothesis sweeps shapes, dtypes-adjacent value ranges and the fuzzifier; the
deterministic tests pin the paper-relevant invariants (padding contract,
membership normalisation, associativity of partials).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fcm_pallas, ref

RTOL = 3e-4
ATOL = 3e-4


def _rand(n, d, c, seed, scale=1.0, offset=0.0):
    key = jax.random.PRNGKey(seed)
    kx, kv, kw = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n, d), jnp.float32) * scale + offset
    v = jax.random.normal(kv, (c, d), jnp.float32) * scale + offset
    w = jnp.abs(jax.random.normal(kw, (n,), jnp.float32)) + 0.05
    return x, v, w


def _check(actual, expected):
    for a, e in zip(actual, expected):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(e), rtol=RTOL, atol=ATOL
        )


# ---------------------------------------------------------------------------
# hypothesis sweeps: shapes × fuzzifier × value range, each kernel vs oracle
# ---------------------------------------------------------------------------

shape_strategy = st.tuples(
    st.sampled_from([64, 128, 256, 512, 1024]),  # chunk (multiple of block)
    st.integers(min_value=1, max_value=48),  # dims
    st.integers(min_value=2, max_value=16),  # clusters
)


@settings(max_examples=25, deadline=None)
@given(
    shape=shape_strategy,
    m=st.sampled_from([1.2, 1.5, 2.0, 3.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fcm_kernel_matches_ref(shape, m, seed):
    n, d, c = shape
    x, v, w = _rand(n, d, c, seed)
    _check(fcm_pallas.fcm_chunk_step(x, v, w, m), ref.fcm_chunk_step(x, v, w, m))


@settings(max_examples=15, deadline=None)
@given(
    shape=st.tuples(
        st.sampled_from([64, 256, 512]),
        st.integers(min_value=1, max_value=32),
        st.integers(min_value=2, max_value=8),
    ),
    m=st.sampled_from([1.2, 2.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_classic_kernel_matches_ref(shape, m, seed):
    n, d, c = shape
    x, v, w = _rand(n, d, c, seed)
    out = fcm_pallas.classic_fcm_chunk_step(x, v, w, m)
    exp = ref.classic_fcm_chunk_step(x, v, w, m)
    # The classic kernel deliberately uses the O(c²) (B,C,C) ratio-tensor
    # formulation while the oracle uses the separable form; at m=1.2 the
    # exponent 1/(m-1)=5 amplifies f32 rounding between the two (observed up
    # to ~1% relative on adversarial hypothesis draws), so the tolerance is
    # much looser than for the fast kernel. The production (fast) kernel is
    # held to 3e-4; this baseline kernel only needs to be the same algorithm.
    for a, e in zip(out, exp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e), rtol=2.5e-2, atol=2.5e-2)


@settings(max_examples=20, deadline=None)
@given(shape=shape_strategy, seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_kmeans_kernel_matches_ref(shape, seed):
    n, d, c = shape
    x, v, w = _rand(n, d, c, seed)
    _check(fcm_pallas.kmeans_chunk_step(x, v, w), ref.kmeans_chunk_step(x, v, w))


@settings(max_examples=10, deadline=None)
@given(
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    offset=st.sampled_from([0.0, 100.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fcm_kernel_value_ranges(scale, offset, seed):
    """Numerical robustness across magnitudes (normalized vs raw features)."""
    x, v, w = _rand(256, 8, 4, seed, scale=scale, offset=offset)
    out = fcm_pallas.fcm_chunk_step(x, v, w, 2.0)
    exp = ref.fcm_chunk_step(x, v, w, 2.0)
    for a, e in zip(out, exp):
        a, e = np.asarray(a), np.asarray(e)
        np.testing.assert_allclose(a, e, rtol=5e-3, atol=5e-3 * max(scale, 1.0))
        assert np.all(np.isfinite(a))


# ---------------------------------------------------------------------------
# deterministic invariants
# ---------------------------------------------------------------------------


def test_zero_weight_rows_are_exactly_ignored():
    """The padding contract: rows with w=0 must not affect any output."""
    x, v, w = _rand(512, 18, 6, 7)
    w_live = w.at[256:].set(0.0)
    full = fcm_pallas.fcm_chunk_step(x, v, w_live, 2.0)
    # Same live rows, garbage in the padded tail.
    x_garbage = x.at[256:].set(1e6)
    padded = fcm_pallas.fcm_chunk_step(x_garbage, v, w_live, 2.0)
    _check(padded, full)


def test_zero_weight_rows_ignored_kmeans():
    x, v, w = _rand(512, 18, 6, 8)
    w_live = w.at[300:].set(0.0)
    full = fcm_pallas.kmeans_chunk_step(x, v, w_live)
    x_garbage = x.at[300:].set(-1e6)
    padded = fcm_pallas.kmeans_chunk_step(x_garbage, v, w_live)
    _check(padded, full)


def test_memberships_sum_to_one():
    x, v, _ = _rand(256, 8, 5, 9)
    u = ref.memberships(x, v, 2.0)
    np.testing.assert_allclose(np.asarray(jnp.sum(u, axis=1)), 1.0, rtol=1e-5)


def test_um_fast_equals_u_power_m():
    """Kolen–Hutcheson identity: the fast term equals U^m elementwise."""
    for m in (1.2, 2.0, 2.5):
        x, v, _ = _rand(128, 6, 4, 10)
        um = ref.um_fast(x, v, m)
        u = ref.memberships(x, v, m)
        np.testing.assert_allclose(
            np.asarray(um), np.asarray(jnp.power(u, m)), rtol=1e-4, atol=1e-6
        )


def test_chunk_partials_are_associative():
    """Two half-chunks must sum to the full-chunk partials — the property
    that makes the MapReduce (combiner) decomposition exact."""
    x, v, w = _rand(512, 12, 4, 11)
    v1, w1, o1 = ref.fcm_chunk_step(x[:256], v, w[:256], 2.0)
    v2, w2, o2 = ref.fcm_chunk_step(x[256:], v, w[256:], 2.0)
    vf, wf, of = ref.fcm_chunk_step(x, v, w, 2.0)
    np.testing.assert_allclose(np.asarray(v1 + v2), np.asarray(vf), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(w1 + w2), np.asarray(wf), rtol=1e-4)
    np.testing.assert_allclose(float(o1 + o2), float(of), rtol=1e-4)


def test_point_on_center_is_finite():
    """A record exactly on a center must not produce NaN/inf (dist clamp)."""
    v = jnp.asarray([[0.0, 0.0], [5.0, 5.0]], jnp.float32)
    x = jnp.asarray([[0.0, 0.0], [1.0, 1.0], [5.0, 5.0]], jnp.float32)
    x = jnp.tile(x, (64, 1))[:64]
    w = jnp.ones(64, jnp.float32)
    out = fcm_pallas.fcm_chunk_step(x, v, w, 2.0)
    for t in out:
        assert np.all(np.isfinite(np.asarray(t)))


def test_uniform_weights_match_unweighted_scaling():
    """Scaling all weights by k scales all partials by k (homogeneity)."""
    x, v, w = _rand(256, 10, 3, 12)
    base = ref.fcm_chunk_step(x, v, w, 2.0)
    scaled = ref.fcm_chunk_step(x, v, 3.0 * w, 2.0)
    for b, s in zip(base, scaled):
        np.testing.assert_allclose(np.asarray(s), 3.0 * np.asarray(b), rtol=1e-4)


def test_kmeans_counts_conserved():
    """Σ counts == Σ weights (every live record lands in exactly one cluster)."""
    x, v, w = _rand(512, 18, 6, 13)
    _, counts, _ = fcm_pallas.kmeans_chunk_step(x, v, w)
    np.testing.assert_allclose(
        float(jnp.sum(counts)), float(jnp.sum(w)), rtol=1e-5
    )


def test_fcm_wacc_conserved():
    """Memberships sum to one per record ⇒ Σ w_acc == Σ w for m where
    u^m sums to... (only for m→1); instead check Σu·w: use classic U."""
    x, v, w = _rand(256, 8, 4, 14)
    u = ref.memberships(x, v, 2.0)
    np.testing.assert_allclose(
        float(jnp.sum(u * w[:, None])), float(jnp.sum(w)), rtol=1e-5
    )


def test_single_row_block_chunk():
    """chunk smaller than ROW_BLOCK still works (blk = chunk)."""
    x, v, w = _rand(64, 4, 3, 15)
    _check(fcm_pallas.fcm_chunk_step(x, v, w, 2.0), ref.fcm_chunk_step(x, v, w, 2.0))


def test_full_artifact_chunk_shape():
    """The production chunk shape (4096 rows) crosses 8 row blocks."""
    x, v, w = _rand(4096, 18, 6, 16)
    _check(fcm_pallas.fcm_chunk_step(x, v, w, 2.0), ref.fcm_chunk_step(x, v, w, 2.0))
